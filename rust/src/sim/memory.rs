//! Memory-system model: double-buffered SRAMs backed by DRAM.
//!
//! The dataflow schedulers annotate each fold with the DRAM bytes its
//! working set requires. With double buffering, the prefetch of fold i+1
//! overlaps fold i's compute; the array stalls only when a fold's demand
//! exceeds `dram_bw × duration`. Bandwidth observations (Fig 11: per-layer
//! average and maximum SRAM/DRAM bandwidth) are taken per fold window.

use super::config::SimConfig;
use super::fold::FoldSet;
use crate::stats::Online;

/// Memory/timing outcome for one layer's fold schedule.
#[derive(Debug, Clone)]
pub struct MemResult {
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub total_cycles: u64,
    /// DRAM traffic (bytes).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// SRAM traffic (bytes, reads + writes).
    pub sram_bytes: u64,
    /// Bandwidth observations in bytes/cycle over fold windows.
    pub dram_bw_avg: f64,
    pub dram_bw_max: f64,
    pub sram_bw_avg: f64,
    pub sram_bw_max: f64,
}

/// Walk the folds, applying the double-buffer stall rule per fold.
pub fn apply(fs: &FoldSet, cfg: &SimConfig) -> MemResult {
    let bpe = cfg.bytes_per_elem as u64;
    let mut compute = 0u64;
    let mut stall = 0u64;
    let mut dram_r = 0u64;
    let mut dram_w = 0u64;
    let mut sram = 0u64;
    let mut dram_bw = Online::new();
    let mut sram_bw = Online::new();

    for f in &fs.folds {
        let demand = f.dram_read_bytes + f.dram_write_bytes;
        // Cycles DRAM needs to move this fold's working set.
        let need = if demand == 0 { 0 } else { (demand as f64 / cfg.dram_bw).ceil() as u64 };
        let fold_stall =
            if cfg.enforce_dram_bw { need.saturating_sub(f.duration) } else { 0 };
        let window = f.duration + fold_stall;

        compute += f.duration * f.count;
        stall += fold_stall * f.count;
        dram_r += f.dram_read_bytes * f.count;
        dram_w += f.dram_write_bytes * f.count;
        let fold_sram = (f.ifmap_reads + f.weight_reads + f.ofmap_writes) * bpe;
        sram += fold_sram * f.count;

        if window > 0 {
            let w = (window * f.count) as f64;
            dram_bw.push_weighted(demand as f64 / window as f64, w);
            sram_bw.push_weighted(fold_sram as f64 / window as f64, w);
        }
    }

    MemResult {
        compute_cycles: compute,
        stall_cycles: stall,
        total_cycles: compute + stall,
        dram_read_bytes: dram_r,
        dram_write_bytes: dram_w,
        sram_bytes: sram,
        dram_bw_avg: if dram_bw.n > 0 { dram_bw.mean() } else { 0.0 },
        dram_bw_max: if dram_bw.n > 0 { dram_bw.max } else { 0.0 },
        sram_bw_avg: if sram_bw.n > 0 { sram_bw.mean() } else { 0.0 },
        sram_bw_max: if sram_bw.n > 0 { sram_bw.max } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fold::Fold;

    fn fold(duration: u64, dram: u64, count: u64) -> Fold {
        Fold {
            duration,
            pe_cycles: 0,
            ifmap_reads: 10,
            weight_reads: 5,
            ofmap_writes: 5,
            dram_read_bytes: dram,
            dram_write_bytes: 0,
            count,
        }
    }

    #[test]
    fn no_stall_when_bandwidth_sufficient() {
        let mut fs = FoldSet::new();
        fs.push(fold(100, 100, 10)); // 1 B/cycle demand, 16 available
        let r = apply(&fs, &SimConfig::default());
        assert_eq!(r.stall_cycles, 0);
        assert_eq!(r.total_cycles, 1000);
        assert!((r.dram_bw_avg - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stall_when_demand_exceeds_bandwidth_and_enforced() {
        let mut fs = FoldSet::new();
        fs.push(fold(10, 320, 4)); // needs 320/16 = 20 cycles > 10
        let mut cfg = SimConfig::default();
        cfg.enforce_dram_bw = true;
        let r = apply(&fs, &cfg);
        assert_eq!(r.stall_cycles, 40); // 10 extra per fold × 4
        assert_eq!(r.total_cycles, 80);
        // bandwidth saturates at the DRAM limit
        assert!((r.dram_bw_max - 16.0).abs() < 1e-9);
    }

    #[test]
    fn default_reports_demand_without_throttling() {
        // SCALE-Sim semantics: the same overdemanding folds run unstalled,
        // and the report shows the bandwidth that WOULD be required.
        let mut fs = FoldSet::new();
        fs.push(fold(10, 320, 4));
        let r = apply(&fs, &SimConfig::default());
        assert_eq!(r.stall_cycles, 0);
        assert_eq!(r.total_cycles, 40);
        assert!((r.dram_bw_max - 32.0).abs() < 1e-9); // demanded, not granted
    }

    #[test]
    fn max_bw_sees_bursts_avg_smooths() {
        let mut fs = FoldSet::new();
        fs.push(fold(100, 800, 1)); // burst: 8 B/cyc
        fs.push(fold(100, 0, 9)); // idle tail
        let r = apply(&fs, &SimConfig::default());
        assert!((r.dram_bw_max - 8.0).abs() < 1e-9);
        assert!((r.dram_bw_avg - 0.8).abs() < 1e-9);
    }

    #[test]
    fn traffic_totals() {
        let mut fs = FoldSet::new();
        let mut f = fold(10, 64, 3);
        f.dram_write_bytes = 16;
        fs.push(f);
        let r = apply(&fs, &SimConfig::default());
        assert_eq!(r.dram_read_bytes, 192);
        assert_eq!(r.dram_write_bytes, 48);
        assert_eq!(r.sram_bytes, 60);
    }
}
