//! Cycle-level systolic-array simulator — the SCALE-Sim-FuSe substrate
//! (DESIGN.md S1). Behavioral fidelity: fold-granular schedules with exact
//! MAC conservation, skew fill/drain per dataflow, double-buffered SRAM +
//! DRAM stall model, per-window bandwidth observation.

pub mod config;
pub mod engine;
pub mod fold;
pub mod gemm;
pub mod global_cache;
pub mod memory;
pub mod stos;
pub mod sweep;
pub mod trace;

pub use config::{Dataflow, MappingPolicy, SimConfig, ALL_DATAFLOWS};
pub use engine::{price_layer, simulate_layer, simulate_network, LayerSim, NetworkSim};
pub use global_cache::{ResultCache, ResultCacheStats};
pub use sweep::{
    grid_configs, run_sweep, run_sweep_coalesced, run_sweep_serial, run_sweep_with,
    simulate_network_cached, CacheStats, FuseVariant, LayerCache, SweepEvent, SweepOutcome,
    SweepPlan, SweepRecord,
};
