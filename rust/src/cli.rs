//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports the subset the `fuseconv` binary, examples, and bench targets
//! need: subcommands, `--flag`, `--key value` / `--key=value`, and trailing
//! positionals, with typed getters and a generated usage string.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Declarative CLI definition for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

/// Parse result: option map + positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue { key: String, value: String, want: &'static str },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::BadValue { key, value, want } => {
                write!(f, "option --{key}: cannot parse {value:?} as {want}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Cli {
        Cli { name, about, specs: Vec::new() }
    }

    /// `--name <value>` option with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Cli {
        self.specs.push(ArgSpec { name, help, takes_value: true, default });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Cli {
        self.specs.push(ArgSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\noptions:");
        for spec in &self.specs {
            let lhs = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let dflt = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(s, "  {lhs:<24} {}{dflt}", spec.help);
        }
        s
    }

    /// Parse raw argv tokens (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(key.clone()))?,
                    };
                    out.values.insert(key, v);
                } else {
                    out.flags.push(key);
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("missing option --{name} (no default)"))
            .to_string()
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::BadValue { key: name.to_string(), value: v, want: "usize" })
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::BadValue { key: name.to_string(), value: v, want: "u64" })
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::BadValue { key: name.to_string(), value: v, want: "f64" })
    }

    /// Typed getter for defaultless options: `None` when absent, an error
    /// only when present-but-unparsable.
    fn opt_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        want: &'static str,
    ) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| CliError::BadValue {
                key: name.to_string(),
                value: v.to_string(),
                want,
            }),
        }
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.opt_parse(name, "usize")
    }

    pub fn opt_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.opt_parse(name, "u64")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("size", "array size", Some("16"))
            .opt("model", "network", None)
            .flag("verbose", "chatty")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&v(&[])).unwrap();
        assert_eq!(a.usize("size").unwrap(), 16);
        assert!(a.get("model").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cli().parse(&v(&["--size", "32", "--model=mbv2"])).unwrap();
        assert_eq!(a.usize("size").unwrap(), 32);
        assert_eq!(a.str("model"), "mbv2");
    }

    #[test]
    fn flags_and_positionals() {
        let a = cli().parse(&v(&["--verbose", "run", "fast"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "fast"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(cli().parse(&v(&["--nope"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(cli().parse(&v(&["--model"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_typed_value() {
        let a = cli().parse(&v(&["--size", "large"])).unwrap();
        assert!(matches!(a.usize("size"), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn optional_typed_getters() {
        let a = cli().parse(&v(&[])).unwrap();
        assert_eq!(a.opt_usize("model").unwrap(), None);
        let a = cli().parse(&v(&["--model", "12"])).unwrap();
        assert_eq!(a.opt_usize("model").unwrap(), Some(12));
        let a = cli().parse(&v(&["--model", "dozen"])).unwrap();
        assert!(a.opt_usize("model").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--size"));
        assert!(u.contains("--verbose"));
        assert!(u.contains("default: 16"));
    }
}
