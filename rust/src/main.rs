//! `fuseconv` — CLI entry point for the FuSeConv/ST-OS/NOS reproduction.
//!
//! Subcommands:
//!   zoo        list networks with MACs/params
//!   simulate   run one network through the systolic simulator
//!   sweep      parallel networks × variants × configs sweep (shared cache)
//!   speedup    baseline-vs-FuSe comparison (Fig 8a style)
//!   vlsi       ST-OS area/power overheads (Table 2)
//!   search-ea  hybrid evolutionary search (Fig 13)
//!   search-nas OFA-space NAS with FuSe choice (Fig 15)
//!   trace      per-layer cycle trace CSV
//!   train      end-to-end NOS pipeline on the AOT artifacts
//!   serve      batched inference serving demo on the AOT artifacts

use fuseconv::cli::Cli;
use fuseconv::coordinator::search::{
    run_ea, run_nas, AccuracyPredictor, EaConfig, NasConfig, TrainMethod,
};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, Variant};
use fuseconv::sim::{
    grid_configs, run_sweep, run_sweep_serial, simulate_network, Dataflow, FuseVariant,
    LayerCache, SimConfig, SweepPlan,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_help();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let code = match cmd.as_str() {
        "zoo" => cmd_zoo(),
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "speedup" => cmd_speedup(&rest),
        "vlsi" => cmd_vlsi(),
        "search-ea" => cmd_search_ea(&rest),
        "search-nas" => cmd_search_nas(&rest),
        "trace" => cmd_trace(&rest),
        "train" => cmd_train(&rest),
        "serve" => cmd_serve(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "fuseconv — FuSeConv + ST-OS + NOS (Ganesan & Kumar, 2021) reproduction\n\n\
         usage: fuseconv <subcommand> [options]\n\n\
         subcommands:\n  \
         zoo         list model zoo with MACs/params\n  \
         simulate    simulate one network  (--model, --size, --dataflow os|ws, --no-stos)\n  \
         sweep       parallel zoo×config sweep (--models, --variants, --sizes, --dataflows,\n              \
                     --stos on|off|both, --threads, --format table|csv|json, --out, --verify)\n  \
         speedup     Fig 8a comparison     (--size)\n  \
         vlsi        Table 2 ST-OS overheads\n  \
         search-ea   hybrid EA search      (--model, --pop, --iters, --seed)\n  \
         search-nas  OFA NAS               (--pop, --iters, --seed, --no-fuse)\n  \
         trace       cycle trace CSV       (--model, --layer)\n  \
         train       NOS pipeline on artifacts (--steps, --artifacts)\n  \
         serve       serving demo          (--requests, --artifacts)"
    );
}

fn sim_config(args: &fuseconv::cli::Args) -> SimConfig {
    let size = args.usize("size").unwrap_or(16);
    let mut cfg = SimConfig::with_size(size);
    if args.get("dataflow") == Some("ws") {
        cfg.dataflow = Dataflow::WeightStationary;
    }
    if args.flag("no-stos") {
        cfg.stos = false;
    }
    cfg
}

fn cmd_zoo() -> i32 {
    println!("{:28} {:>10} {:>11} {:>8}", "network", "MACs (M)", "params (M)", "blocks");
    for name in models::ZOO_NAMES {
        let net = models::by_name(name).unwrap();
        println!(
            "{:28} {:>10.1} {:>11.2} {:>8}",
            name,
            net.macs_millions(),
            net.params_millions(),
            net.bottleneck_blocks().len()
        );
    }
    0
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let cli = Cli::new("simulate", "simulate a network on the systolic array")
        .opt("model", "zoo network name", Some("mobilenet-v2"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws", Some("os"))
        .flag("no-stos", "disable ST-OS broadcast support")
        .flag("fuse", "apply FuSe-Half transform first")
        .flag("layers", "print per-layer detail");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let Some(mut net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model; try `fuseconv zoo`");
        return 2;
    };
    if args.flag("fuse") {
        net = fuse_all(&net, Variant::Half);
    }
    let cfg = sim_config(&args);
    let sim = simulate_network(&net, &cfg);
    println!(
        "{} on {}: {:.3} ms ({} cycles), util {:.1}%",
        sim.network,
        sim.config_label,
        sim.latency_ms,
        sim.total_cycles,
        100.0 * sim.overall_utilization()
    );
    for (class, cycles) in sim.cycles_by_class() {
        println!("  {:?}: {:.1}%", class, 100.0 * cycles as f64 / sim.total_cycles as f64);
    }
    if args.flag("layers") {
        for l in &sim.layers {
            println!(
                "  {:32} {:>10} cycles  util {:>5.1}%  dram {:>6.1} B/cyc avg",
                l.name,
                l.total_cycles,
                100.0 * l.utilization,
                l.mem.dram_bw_avg
            );
        }
    }
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cli = Cli::new("sweep", "parallel networks × variants × configs simulation sweep")
        .opt("models", "paper5 | all | comma-separated zoo names", Some("paper5"))
        .opt("variants", "comma list of base,half,full", Some("base,half,full"))
        .opt("sizes", "comma list of square array sizes", Some("8,16,32,64"))
        .opt("dataflows", "comma list of os,ws", Some("os"))
        .opt("stos", "on | off | both", Some("on"))
        .opt("threads", "worker threads (0=auto)", Some("0"))
        .opt("format", "table | csv | json", Some("table"))
        .opt("out", "write csv/json to this file", None)
        .flag("verify", "re-run serially and check bit-identical cycle counts");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };

    // --- grid spec parsing ---
    let networks: Vec<fuseconv::nn::Network> = match args.str("models").as_str() {
        "paper5" => models::paper_five(),
        "all" => models::ZOO_NAMES.iter().map(|n| models::by_name(n).unwrap()).collect(),
        list => {
            let mut nets = Vec::new();
            for name in list.split(',').filter(|s| !s.is_empty()) {
                match models::by_name(name) {
                    Some(n) => nets.push(n),
                    None => {
                        eprintln!("unknown model {name:?}; try `fuseconv zoo`");
                        return 2;
                    }
                }
            }
            nets
        }
    };
    let mut variants = Vec::new();
    for v in args.str("variants").split(',').filter(|s| !s.is_empty()) {
        variants.push(match v {
            "base" => FuseVariant::Base,
            "half" => FuseVariant::Half,
            "full" => FuseVariant::Full,
            other => {
                eprintln!("unknown variant {other:?} (want base|half|full)");
                return 2;
            }
        });
    }
    let mut sizes = Vec::new();
    for s in args.str("sizes").split(',').filter(|s| !s.is_empty()) {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => sizes.push(n),
            _ => {
                eprintln!("bad array size {s:?}");
                return 2;
            }
        }
    }
    let mut dataflows = Vec::new();
    for d in args.str("dataflows").split(',').filter(|s| !s.is_empty()) {
        dataflows.push(match d {
            "os" => Dataflow::OutputStationary,
            "ws" => Dataflow::WeightStationary,
            other => {
                eprintln!("unknown dataflow {other:?} (want os|ws)");
                return 2;
            }
        });
    }
    let stos_modes: Vec<bool> = match args.str("stos").as_str() {
        "on" => vec![true],
        "off" => vec![false],
        "both" => vec![true, false],
        other => {
            eprintln!("bad --stos {other:?} (want on|off|both)");
            return 2;
        }
    };

    let plan = SweepPlan::new(networks, variants, grid_configs(&sizes, &dataflows, &stos_modes));
    if plan.is_empty() {
        eprintln!("empty sweep (no models, variants, or configs)");
        return 2;
    }

    // --- run ---
    let threads = match args.usize("threads") {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let pool = fuseconv::exec::Pool::new(threads);
    let cache = std::sync::Arc::new(LayerCache::new());
    let t0 = std::time::Instant::now();
    let out = run_sweep(&plan, &pool, &cache);
    let wall = t0.elapsed().as_secs_f64();

    // --- report ---
    match args.str("format").as_str() {
        "csv" => print!("{}", out.to_csv()),
        "json" => println!("{}", out.to_json()),
        _ => {
            println!(
                "{:26} {:10} {:20} {:>14} {:>10} {:>7}",
                "network", "variant", "config", "cycles", "ms", "util"
            );
            for r in out.records() {
                println!(
                    "{:26} {:10} {:20} {:>14} {:>10.3} {:>6.1}%",
                    r.network,
                    r.variant.label(),
                    r.cfg.label(),
                    r.sim.total_cycles,
                    r.sim.latency_ms,
                    100.0 * r.sim.overall_utilization()
                );
            }
        }
    }
    if let Some(path) = args.get("out") {
        let body = if args.str("format") == "json" { out.to_json() } else { out.to_csv() };
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("# wrote {path}");
    }
    let cs = out.cache_stats;
    eprintln!(
        "# {} simulations on {} threads in {wall:.2}s; shared layer cache: {} hits / {} misses \
         ({:.1}% hit rate, {} entries; schedule reuse {} hits)",
        plan.len(),
        pool.threads(),
        cs.hits,
        cs.misses,
        100.0 * cs.hit_rate(),
        cs.entries,
        cs.sched_hits,
    );

    // --- serial cross-check ---
    if args.flag("verify") {
        let serial = run_sweep_serial(&plan);
        let mut bad = 0;
        for (a, b) in serial.records().iter().zip(out.records()) {
            if a.total_cycles() != b.total_cycles() {
                eprintln!(
                    "MISMATCH {} {} {}: serial {} != parallel {}",
                    a.network,
                    a.variant.label(),
                    a.cfg.label(),
                    a.total_cycles(),
                    b.total_cycles()
                );
                bad += 1;
            }
        }
        if bad > 0 {
            eprintln!("# verify FAILED: {bad}/{} cells differ", plan.len());
            return 1;
        }
        eprintln!("# verify OK: all {} cells bit-identical to the serial path", plan.len());
    }
    0
}

fn cmd_speedup(argv: &[String]) -> i32 {
    let cli = Cli::new("speedup", "Fig 8a: baseline vs FuSe on the array")
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws", Some("os"))
        .flag("no-stos", "unused (always on for FuSe runs)");
    let args = cli.parse(argv).unwrap();
    let cfg = sim_config(&args);
    println!(
        "{:22} {:>9} {:>9} {:>9} {:>7} {:>7}",
        "network", "base ms", "half ms", "full ms", "spd-H", "spd-F"
    );
    for net in models::paper_five() {
        let sb = simulate_network(&net, &cfg);
        let sh = simulate_network(&fuse_all(&net, Variant::Half), &cfg);
        let sf = simulate_network(&fuse_all(&net, Variant::Full), &cfg);
        println!(
            "{:22} {:>9.3} {:>9.3} {:>9.3} {:>6.2}x {:>6.2}x",
            net.name,
            sb.latency_ms,
            sh.latency_ms,
            sf.latency_ms,
            sb.total_cycles as f64 / sh.total_cycles as f64,
            sb.total_cycles as f64 / sf.total_cycles as f64
        );
    }
    0
}

fn cmd_vlsi() -> i32 {
    println!("{:>10} {:>12} {:>12}   (paper Table 2)", "array", "area ovh %", "power ovh %");
    for s in fuseconv::vlsi::table2_sizes() {
        let o = fuseconv::vlsi::st_os_overhead(s, s);
        println!("{:>7}x{:<3} {:>12.1} {:>12.1}", s, s, o.area_pct(), o.power_pct());
    }
    0
}

fn cmd_search_ea(argv: &[String]) -> i32 {
    let cli = Cli::new("search-ea", "evolutionary hybrid search")
        .opt("model", "base network", Some("mobilenet-v3-large"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws", Some("os"))
        .opt("pop", "population", Some("100"))
        .opt("iters", "iterations", Some("100"))
        .opt("seed", "rng seed", Some("42"))
        .flag("no-stos", "disable ST-OS")
        .flag("in-place", "predict without NOS");
    let args = cli.parse(argv).unwrap();
    let Some(net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model");
        return 2;
    };
    let ev = Evaluator::new(sim_config(&args));
    let space = HybridSpace::new(&net, &ev);
    let pred = AccuracyPredictor::for_space(&space);
    let method = if args.flag("in-place") { TrainMethod::InPlace } else { TrainMethod::Nos };
    let cfg = EaConfig {
        population: args.usize("pop").unwrap(),
        iterations: args.usize("iters").unwrap(),
        seed: args.u64("seed").unwrap(),
        ..EaConfig::default()
    };
    let t0 = std::time::Instant::now();
    let r = run_ea(&space, &pred, method, &cfg);
    println!(
        "# evaluated {} candidates in {:.2}s; frontier:",
        r.evaluated,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>8} {:>9} {:>10} {:>11}  mask (F=FuSe, d=depthwise)", "acc %", "lat ms", "MACs (M)", "params (M)");
    for c in &r.frontier {
        let mask: String = c.mask.iter().map(|&m| if m { 'F' } else { 'd' }).collect();
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}  {}",
            c.acc,
            c.latency_ms,
            c.macs as f64 / 1e6,
            c.params as f64 / 1e6,
            mask
        );
    }
    0
}

fn cmd_search_nas(argv: &[String]) -> i32 {
    let cli = Cli::new("search-nas", "OFA-space NAS")
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws", Some("os"))
        .opt("pop", "population", Some("32"))
        .opt("iters", "iterations", Some("16"))
        .opt("seed", "rng seed", Some("42"))
        .opt("threads", "worker threads (0=auto)", Some("0"))
        .flag("no-stos", "disable ST-OS")
        .flag("no-fuse", "search without the FuSe operator (baseline OFA)");
    let args = cli.parse(argv).unwrap();
    let ev = std::sync::Arc::new(Evaluator::new(sim_config(&args)));
    let cfg = NasConfig {
        population: args.usize("pop").unwrap(),
        iterations: args.usize("iters").unwrap(),
        seed: args.u64("seed").unwrap(),
        threads: args.usize("threads").unwrap(),
        allow_fuse: !args.flag("no-fuse"),
        ..NasConfig::default()
    };
    let t0 = std::time::Instant::now();
    let r = run_nas(ev, &cfg);
    println!(
        "# evaluated {} genomes in {:.2}s; frontier:",
        r.evaluated,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>8} {:>9} {:>10} {:>11}", "acc %", "lat ms", "MACs (M)", "params (M)");
    for c in &r.frontier {
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}",
            c.acc, c.latency_ms, c.macs_millions, c.params_millions
        );
    }
    0
}

fn cmd_trace(argv: &[String]) -> i32 {
    let cli = Cli::new("trace", "cycle-trace one layer")
        .opt("model", "zoo network", Some("mobilenet-v2"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws", Some("os"))
        .opt("layer", "layer index", Some("1"))
        .opt("windows", "max trace windows", Some("64"))
        .flag("no-stos", "disable ST-OS")
        .flag("fuse", "FuSe-Half transform first");
    let args = cli.parse(argv).unwrap();
    let Some(mut net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model");
        return 2;
    };
    if args.flag("fuse") {
        net = fuse_all(&net, Variant::Half);
    }
    let idx = args.usize("layer").unwrap();
    if idx >= net.layers.len() {
        eprintln!("layer {idx} out of range ({} layers)", net.layers.len());
        return 2;
    }
    let cfg = sim_config(&args);
    let fs = fuseconv::sim::engine::schedule_layer(&net.layers[idx], &cfg);
    let trace = fuseconv::sim::trace::expand(&fs, args.usize("windows").unwrap());
    print!("# {} / {}\n{}", net.name, net.layers[idx].name, fuseconv::sim::trace::to_csv(&trace));
    0
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_argv: &[String]) -> i32 {
    eprintln!("`train` needs the PJRT runtime; rebuild with `--features xla`");
    1
}

#[cfg(not(feature = "xla"))]
fn cmd_serve(_argv: &[String]) -> i32 {
    eprintln!("`serve` needs the PJRT runtime; rebuild with `--features xla`");
    1
}

#[cfg(feature = "xla")]
fn cmd_train(argv: &[String]) -> i32 {
    let cli = Cli::new("train", "end-to-end NOS pipeline on AOT artifacts")
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("steps", "training steps per phase", Some("150"))
        .opt("lr", "initial learning rate", Some("0.06"))
        .opt("seed", "data seed", Some("17"))
        .opt("eval", "eval samples", Some("256"));
    let args = cli.parse(argv).unwrap();
    match fuseconv::runtime::pipeline::run_nos_pipeline(
        &args.str("artifacts"),
        args.usize("steps").unwrap(),
        args.f64("lr").unwrap() as f32,
        args.u64("seed").unwrap(),
        args.usize("eval").unwrap(),
        true,
    ) {
        Ok(_) => 0,
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

#[cfg(feature = "xla")]
fn cmd_serve(argv: &[String]) -> i32 {
    let cli = Cli::new("serve", "batched serving demo")
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("requests", "number of requests", Some("64"))
        .opt("max-batch", "dynamic batch cap", Some("8"))
        .opt("max-wait-ms", "batch deadline", Some("5"));
    let args = cli.parse(argv).unwrap();
    use fuseconv::coordinator::batcher::BatchPolicy;
    use fuseconv::coordinator::server::Server;
    use fuseconv::runtime::{PjrtEngine, Synth};

    let dir = std::path::PathBuf::from(args.str("artifacts"));
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built; run `make artifacts`");
        return 1;
    }
    let manifest = fuseconv::runtime::Manifest::load(&dir).unwrap();
    let hw = manifest.const_usize("image_hw").unwrap();
    let classes = manifest.const_usize("num_classes").unwrap();
    let policy = BatchPolicy {
        max_batch: args.usize("max-batch").unwrap(),
        max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms").unwrap()),
    };
    let server = Server::start_with(
        move || PjrtEngine::from_artifacts(&dir, "student_init.bin").unwrap(),
        policy,
    );
    let n = args.usize("requests").unwrap();
    let mut synth = Synth::new(hw, classes, 99);
    let mut pending = Vec::new();
    for _ in 0..n {
        let (x, _) = synth.batch(1);
        pending.push(server.submit(x));
    }
    for rx in pending {
        let _ = rx.recv_timeout(std::time::Duration::from_secs(300)).expect("response");
    }
    let stats = server.shutdown();
    let s = stats.latency_summary().unwrap();
    println!(
        "served {} requests in {} batches (mean batch {:.1})",
        stats.served,
        stats.batches,
        stats.mean_batch()
    );
    println!("latency_us: p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0}", s.p50, s.p90, s.p99, s.max);
    0
}
