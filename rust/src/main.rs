//! `fuseconv` — CLI entry point for the FuSeConv/ST-OS/NOS reproduction.
//!
//! Subcommands:
//!   zoo        list networks with MACs/params
//!   simulate   run one network through the systolic simulator
//!   sweep      parallel networks × variants × configs sweep (shared cache)
//!   speedup    baseline-vs-FuSe comparison (Fig 8a style)
//!   vlsi       ST-OS area/power overheads (Table 2)
//!   search-ea  hybrid evolutionary search (Fig 13)
//!   search-nas OFA-space NAS with FuSe choice (Fig 15)
//!   search     streaming NAS job: local, or on a serve/shard endpoint
//!              via the `search` op (--remote, --http for SSE), with
//!              per-generation progress and live Pareto rows
//!   trace      per-layer cycle trace CSV
//!   train      end-to-end NOS pipeline on the AOT artifacts
//!   serve      serving frontends: TCP/JSON frames, plus HTTP/SSE with
//!              --http-port (inference + simulation traffic, protocol v2
//!              frame streams, two-lane admission, one shared router)
//!   shard      multi-node front tier over several `fuseconv serve`
//!              backends: (model, config)-sharded routing, plan-order
//!              sweep merge, aggregated stats, fan-out shutdown
//!   request    client for a running `fuseconv serve`/`fuseconv shard`
//!              (--stream for the raw frame view, --http for HTTP)

use fuseconv::cli::Cli;
use fuseconv::coordinator::search::{
    run_ea, run_nas, AccuracyPredictor, EaConfig, NasConfig, TrainMethod,
};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, Variant};
use fuseconv::sim::{
    grid_configs, run_sweep, run_sweep_serial, simulate_network, Dataflow, FuseVariant,
    LayerCache, ResultCache, SimConfig, SweepPlan,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_help();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let code = match cmd.as_str() {
        "zoo" => cmd_zoo(),
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "speedup" => cmd_speedup(&rest),
        "vlsi" => cmd_vlsi(),
        "search-ea" => cmd_search_ea(&rest),
        "search-nas" => cmd_search_nas(&rest),
        "search" => cmd_search(&rest),
        "trace" => cmd_trace(&rest),
        "train" => cmd_train(&rest),
        "serve" => cmd_serve(&rest),
        "shard" => cmd_shard(&rest),
        "request" => cmd_request(&rest),
        "bench" => fuseconv::bench::cmd_bench(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "fuseconv — FuSeConv + ST-OS + NOS (Ganesan & Kumar, 2021) reproduction\n\n\
         usage: fuseconv <subcommand> [options]\n\n\
         subcommands:\n  \
         zoo         list model zoo with MACs/params\n  \
         simulate    simulate one network  (--model, --size, --dataflow os|ws|is, --no-stos)\n  \
         sweep       parallel zoo×config sweep (--models, --variants, --sizes, --dataflows,\n              \
                     --stos on|off|both, --threads, --format table|csv|json, --out, --verify,\n              \
                     --remote host:port to stream the grid from a serve endpoint)\n  \
         speedup     Fig 8a comparison     (--size)\n  \
         vlsi        Table 2 ST-OS overheads\n  \
         search-ea   hybrid EA search      (--model, --pop, --iters, --seed)\n  \
         search-nas  OFA NAS               (--pop, --iters, --seed, --no-fuse)\n  \
         search      streaming NAS job     (--pop, --iters, --mutation-p, --seed, --no-fuse,\n              \
                     --remote host:port to run it on a serve/shard endpoint, --http for SSE,\n              \
                     --token for authenticated endpoints, --rows for live pareto rows)\n  \
         trace       cycle trace CSV       (--model, --layer)\n  \
         train       NOS pipeline on artifacts (--steps, --artifacts)\n  \
         serve       TCP + HTTP frontends  (--listen, --http-port, --engine mock|none|pjrt,\n              \
                     --transport threaded|epoll, --threads, --sim-capacity, --batch-capacity,\n              \
                     --search-capacity, --cache-entries, --max-requests-per-conn, --queue,\n              \
                     --auth-token, --port-file, --http-port-file)\n  \
         shard       multi-node front tier (--backends addr1,addr2,..., --listen, --http-port,\n              \
                     --transport threaded|epoll, --timeout-ms, --probe-interval-ms, --probe-failures,\n              \
                     --max-requests-per-conn, --auth-token, --port-file, --http-port-file)\n  \
         request     serve client          (--connect, --op infer|simulate|sweep|stats|zoo|cancel|\n              \
                     add-backend|drain-backend|shutdown, --backend host:port,\n              \
                     --model, --model-file spec.json, --variant, --size, --count,\n              \
                     --stream, --http, --token)\n  \
         bench       open-loop load generator (--connect, --rps, --connections, --duration-secs,\n              \
                     --warmup-secs, --mix simulate=80,infer=10,sweep=10, --out BENCH_6.json)"
    );
}

/// Build a `SimConfig` from the shared `--size/--dataflow/--no-stos`
/// options. Unknown `--dataflow` values are a usage error (they used to
/// fall through to output-stationary silently); the wire protocol's
/// config parsing shares the same [`Dataflow::parse`] validation.
fn sim_config(args: &fuseconv::cli::Args) -> Result<SimConfig, String> {
    let size = args.usize("size").map_err(|e| e.to_string())?;
    let mut cfg = SimConfig::with_size(size);
    if let Some(df) = args.get("dataflow") {
        cfg.dataflow =
            Dataflow::parse(df).ok_or_else(|| format!("bad --dataflow {df:?} (want os|ws|is)"))?;
    }
    if args.flag("no-stos") {
        cfg.stos = false;
    }
    Ok(cfg)
}

/// [`sim_config`], reporting failures against `cli`'s usage text — the
/// one error path shared by every subcommand taking the config flags.
fn sim_config_or_usage(args: &fuseconv::cli::Args, cli: &Cli) -> Option<SimConfig> {
    match sim_config(args) {
        Ok(cfg) => Some(cfg),
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            None
        }
    }
}

fn cmd_zoo() -> i32 {
    println!("{:28} {:>10} {:>11} {:>8}", "network", "MACs (M)", "params (M)", "blocks");
    for (name, macs_m, params_m, blocks) in models::zoo_table() {
        println!("{:28} {:>10.1} {:>11.2} {:>8}", name, macs_m, params_m, blocks);
    }
    0
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let cli = Cli::new("simulate", "simulate a network on the systolic array")
        .opt("model", "zoo network name", Some("mobilenet-v2"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .flag("no-stos", "disable ST-OS broadcast support")
        .flag("fuse", "apply FuSe-Half transform first")
        .flag("layers", "print per-layer detail");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let Some(mut net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model; try `fuseconv zoo`");
        return 2;
    };
    if args.flag("fuse") {
        net = fuse_all(&net, Variant::Half);
    }
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    let sim = simulate_network(&net, &cfg);
    println!(
        "{} on {}: {:.3} ms ({} cycles), util {:.1}%",
        sim.network,
        sim.config_label,
        sim.latency_ms,
        sim.total_cycles,
        100.0 * sim.overall_utilization()
    );
    for (class, cycles) in sim.cycles_by_class() {
        println!("  {:?}: {:.1}%", class, 100.0 * cycles as f64 / sim.total_cycles as f64);
    }
    if args.flag("layers") {
        for l in &sim.layers {
            println!(
                "  {:32} {:>10} cycles  util {:>5.1}%  dram {:>6.1} B/cyc avg",
                l.name,
                l.total_cycles,
                100.0 * l.utilization,
                l.mem.dram_bw_avg
            );
        }
    }
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cli = Cli::new("sweep", "parallel networks × variants × configs simulation sweep")
        .opt("models", "paper5 | all | comma-separated zoo names", Some("paper5"))
        .opt("variants", "comma list of base,half,full", Some("base,half,full"))
        .opt("sizes", "comma list of square array sizes", Some("8,16,32,64"))
        .opt("dataflows", "comma list of os,ws,is", Some("os"))
        .opt("stos", "on | off | both", Some("on"))
        .opt("threads", "worker threads (0=auto; local runs only)", Some("0"))
        .opt("format", "table | csv | json", Some("table"))
        .opt("out", "write csv/json to this file", None)
        .opt("remote", "stream the sweep from a `fuseconv serve` endpoint", None)
        .opt("timeout-ms", "remote receive timeout", Some("600000"))
        .flag("verify", "re-run serially and check bit-identical cycle counts");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };

    // --- grid spec parsing (zoo names first: the wire protocol addresses
    //     models by name, and the local path resolves the same list) ---
    let names: Vec<String> = match args.str("models").as_str() {
        "paper5" => models::PAPER_FIVE_NAMES.iter().map(|s| s.to_string()).collect(),
        "all" => models::ZOO_NAMES.iter().map(|s| s.to_string()).collect(),
        list => list.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect(),
    };
    let mut networks: Vec<fuseconv::nn::Network> = Vec::with_capacity(names.len());
    for name in &names {
        match models::by_name(name) {
            Some(n) => networks.push(n),
            None => {
                eprintln!("unknown model {name:?}; try `fuseconv zoo`");
                return 2;
            }
        }
    }
    let mut variants = Vec::new();
    for v in args.str("variants").split(',').filter(|s| !s.is_empty()) {
        match FuseVariant::parse(v) {
            Some(variant) => variants.push(variant),
            None => {
                eprintln!("unknown variant {v:?} (want base|half|full)");
                return 2;
            }
        }
    }
    let mut sizes = Vec::new();
    for s in args.str("sizes").split(',').filter(|s| !s.is_empty()) {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => sizes.push(n),
            _ => {
                eprintln!("bad array size {s:?}");
                return 2;
            }
        }
    }
    let mut dataflows = Vec::new();
    for d in args.str("dataflows").split(',').filter(|s| !s.is_empty()) {
        match Dataflow::parse(d) {
            Some(df) => dataflows.push(df),
            None => {
                eprintln!("unknown dataflow {d:?} (want os|ws|is)");
                return 2;
            }
        }
    }
    let stos_modes: Vec<bool> = match args.str("stos").as_str() {
        "on" => vec![true],
        "off" => vec![false],
        "both" => vec![true, false],
        other => {
            eprintln!("bad --stos {other:?} (want on|off|both)");
            return 2;
        }
    };

    let plan = SweepPlan::new(
        networks,
        variants.clone(),
        grid_configs(&sizes, &dataflows, &stos_modes),
    );
    if plan.is_empty() {
        eprintln!("empty sweep (no models, variants, or configs)");
        return 2;
    }

    if args.get("remote").is_some() {
        return sweep_remote(&args, &names, &variants, &plan);
    }

    // --- run ---
    let threads = match args.usize("threads") {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let pool = fuseconv::exec::Pool::new(threads);
    let cache = std::sync::Arc::new(LayerCache::new());
    let t0 = std::time::Instant::now();
    let out = run_sweep(&plan, &pool, &cache);
    let wall = t0.elapsed().as_secs_f64();

    // --- report ---
    match args.str("format").as_str() {
        "csv" => print!("{}", out.to_csv()),
        "json" => println!("{}", out.to_json()),
        _ => {
            println!(
                "{:26} {:10} {:20} {:>14} {:>10} {:>7}",
                "network", "variant", "config", "cycles", "ms", "util"
            );
            for r in out.records() {
                println!(
                    "{:26} {:10} {:20} {:>14} {:>10.3} {:>6.1}%",
                    r.network,
                    r.variant.label(),
                    r.cfg.label(),
                    r.sim.total_cycles,
                    r.sim.latency_ms,
                    100.0 * r.sim.overall_utilization()
                );
            }
        }
    }
    if let Some(path) = args.get("out") {
        let body = if args.str("format") == "json" { out.to_json() } else { out.to_csv() };
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("# wrote {path}");
    }
    let cs = out.cache_stats;
    eprintln!(
        "# {} simulations on {} threads in {wall:.2}s; shared layer cache: {} hits / {} misses \
         ({:.1}% hit rate, {} entries; schedule reuse {} hits)",
        plan.len(),
        pool.threads(),
        cs.hits,
        cs.misses,
        100.0 * cs.hit_rate(),
        cs.entries,
        cs.sched_hits,
    );

    // --- serial cross-check ---
    if args.flag("verify") {
        let serial = run_sweep_serial(&plan);
        let mut bad = 0;
        for (a, b) in serial.records().iter().zip(out.records()) {
            if a.total_cycles() != b.total_cycles() {
                eprintln!(
                    "MISMATCH {} {} {}: serial {} != parallel {}",
                    a.network,
                    a.variant.label(),
                    a.cfg.label(),
                    a.total_cycles(),
                    b.total_cycles()
                );
                bad += 1;
            }
        }
        if bad > 0 {
            eprintln!("# verify FAILED: {bad}/{} cells differ", plan.len());
            return 1;
        }
        eprintln!("# verify OK: all {} cells bit-identical to the serial path", plan.len());
    }
    0
}

/// CSV for wire sweep rows (the remote stream carries the serving-sized
/// row digest — no per-layer utilization/MACs columns).
fn rows_csv(rows: &[fuseconv::coordinator::SweepRow]) -> String {
    let mut s = String::from("network,variant,rows,cols,dataflow,stos,total_cycles,latency_ms\n");
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{:.6}\n",
            r.network,
            r.variant.label(),
            r.rows,
            r.cols,
            r.dataflow.short(),
            r.stos,
            r.total_cycles,
            r.latency_ms,
        ));
    }
    s
}

fn rows_json(rows: &[fuseconv::coordinator::SweepRow]) -> String {
    use fuseconv::coordinator::wire::Json;
    // Built on the wire codec's JSON writer, so escaping and number
    // formatting match the frames the rows arrived in.
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::Obj(vec![
                    ("network".into(), Json::Str(r.network.clone())),
                    ("variant".into(), Json::Str(r.variant.label().into())),
                    ("rows".into(), Json::UInt(r.rows as u64)),
                    ("cols".into(), Json::UInt(r.cols as u64)),
                    ("dataflow".into(), Json::Str(r.dataflow.short().into())),
                    ("stos".into(), Json::Bool(r.stos)),
                    ("total_cycles".into(), Json::UInt(r.total_cycles)),
                    ("latency_ms".into(), Json::Num(r.latency_ms)),
                ])
            })
            .collect(),
    )
    .to_string()
}

/// `fuseconv sweep --remote`: run the grid on a `fuseconv serve`
/// endpoint over the v2 streaming protocol — rows arrive incrementally
/// (progress on stderr) and are reported, and optionally `--verify`d
/// against a local serial sweep of the same grid, once the stream ends.
fn sweep_remote(
    args: &fuseconv::cli::Args,
    names: &[String],
    variants: &[FuseVariant],
    plan: &SweepPlan,
) -> i32 {
    use fuseconv::coordinator::{
        ConfigPatch, Frame, Request, RequestBody, SweepRow, WireClient,
    };

    let addr = args.str("remote");
    let timeout_ms = match args.u64("timeout-ms") {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // The wire patches are derived from the plan's own config list, so
    // remote rows structurally arrive in local plan order and `--verify`
    // can zip against the serial sweep. The CLI grid only varies
    // geometry/dataflow/ST-OS; everything else stays Table-1 default.
    let configs: Vec<ConfigPatch> = plan
        .configs
        .iter()
        .map(|c| ConfigPatch {
            rows: Some(c.rows),
            cols: Some(c.cols),
            dataflow: Some(c.dataflow),
            stos: Some(c.stos),
            ..ConfigPatch::default()
        })
        .collect();
    let req = Request::new(
        1,
        RequestBody::Sweep {
            models: names.to_vec(),
            variants: variants.to_vec(),
            configs,
        },
    );
    let mut client =
        match WireClient::connect(&addr, std::time::Duration::from_millis(timeout_ms)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("connect {addr}: {e}");
                return 1;
            }
        };
    if let Err(e) = client.send(&req) {
        eprintln!("send: {e}");
        return 1;
    }

    let t0 = std::time::Instant::now();
    let mut rows: Vec<SweepRow> = Vec::new();
    loop {
        match client.recv_frame(req.id) {
            Ok(Frame::Progress { done, total }) => {
                // throttle progress chatter to ~10 stderr lines per sweep
                let step = (total / 10).max(1);
                if done > 0 && (done % step == 0 || done == total) {
                    eprintln!(
                        "# progress {done}/{total} cells ({:.2}s)",
                        t0.elapsed().as_secs_f64()
                    );
                }
            }
            Ok(Frame::Row(row)) => rows.push(row),
            Ok(Frame::Final(Ok(_))) => break,
            Ok(Frame::Final(Err(e))) => {
                eprintln!("remote sweep failed: {e}");
                return 1;
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if rows.len() != plan.len() {
        eprintln!(
            "remote sweep streamed {} rows for a {}-cell grid",
            rows.len(),
            plan.len()
        );
        return 1;
    }

    // --- report ---
    match args.str("format").as_str() {
        "csv" => print!("{}", rows_csv(&rows)),
        "json" => println!("{}", rows_json(&rows)),
        _ => {
            println!(
                "{:26} {:10} {:>6} {:>4} {:>5} {:>14} {:>10}",
                "network", "variant", "array", "df", "stos", "cycles", "ms"
            );
            for r in &rows {
                println!(
                    "{:26} {:10} {:>3}x{:<3} {:>4} {:>5} {:>14} {:>10.3}",
                    r.network,
                    r.variant.label(),
                    r.rows,
                    r.cols,
                    r.dataflow.short(),
                    r.stos,
                    r.total_cycles,
                    r.latency_ms,
                );
            }
        }
    }
    if let Some(path) = args.get("out") {
        let body = if args.str("format") == "json" { rows_json(&rows) } else { rows_csv(&rows) };
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("# wrote {path}");
    }
    eprintln!("# {} rows streamed from {addr} in {wall:.2}s", rows.len());

    // --- serial cross-check: streamed rows vs a local serial sweep ---
    if args.flag("verify") {
        let serial = run_sweep_serial(plan);
        let mut bad = 0;
        for (r, s) in rows.iter().zip(serial.records()) {
            if r.network != s.network
                || r.variant != s.variant
                || r.rows != s.cfg.rows
                || r.total_cycles != s.total_cycles()
            {
                eprintln!(
                    "MISMATCH {} {} {}x{}: remote {} != serial {}",
                    r.network,
                    r.variant.label(),
                    r.rows,
                    r.cols,
                    r.total_cycles,
                    s.total_cycles()
                );
                bad += 1;
            }
        }
        if bad > 0 {
            eprintln!("# verify FAILED: {bad}/{} cells differ", plan.len());
            return 1;
        }
        eprintln!(
            "# verify OK: all {} streamed rows bit-identical to the local serial sweep",
            plan.len()
        );
    }
    0
}

fn cmd_speedup(argv: &[String]) -> i32 {
    let cli = Cli::new("speedup", "Fig 8a: baseline vs FuSe on the array")
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .flag("no-stos", "unused (always on for FuSe runs)");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    println!(
        "{:22} {:>9} {:>9} {:>9} {:>7} {:>7}",
        "network", "base ms", "half ms", "full ms", "spd-H", "spd-F"
    );
    for net in models::paper_five() {
        let sb = simulate_network(&net, &cfg);
        let sh = simulate_network(&fuse_all(&net, Variant::Half), &cfg);
        let sf = simulate_network(&fuse_all(&net, Variant::Full), &cfg);
        println!(
            "{:22} {:>9.3} {:>9.3} {:>9.3} {:>6.2}x {:>6.2}x",
            net.name,
            sb.latency_ms,
            sh.latency_ms,
            sf.latency_ms,
            sb.total_cycles as f64 / sh.total_cycles as f64,
            sb.total_cycles as f64 / sf.total_cycles as f64
        );
    }
    0
}

fn cmd_vlsi() -> i32 {
    println!("{:>10} {:>12} {:>12}   (paper Table 2)", "array", "area ovh %", "power ovh %");
    for s in fuseconv::vlsi::table2_sizes() {
        let o = fuseconv::vlsi::st_os_overhead(s, s);
        println!("{:>7}x{:<3} {:>12.1} {:>12.1}", s, s, o.area_pct(), o.power_pct());
    }
    0
}

fn cmd_search_ea(argv: &[String]) -> i32 {
    let cli = Cli::new("search-ea", "evolutionary hybrid search")
        .opt("model", "base network", Some("mobilenet-v3-large"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .opt("pop", "population", Some("100"))
        .opt("iters", "iterations", Some("100"))
        .opt("seed", "rng seed", Some("42"))
        .flag("no-stos", "disable ST-OS")
        .flag("in-place", "predict without NOS");
    let args = cli.parse(argv).unwrap();
    let Some(net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model");
        return 2;
    };
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    let ev = Evaluator::new(cfg);
    let space = HybridSpace::new(&net, &ev);
    let pred = AccuracyPredictor::for_space(&space);
    let method = if args.flag("in-place") { TrainMethod::InPlace } else { TrainMethod::Nos };
    let cfg = EaConfig {
        population: args.usize("pop").unwrap(),
        iterations: args.usize("iters").unwrap(),
        seed: args.u64("seed").unwrap(),
        ..EaConfig::default()
    };
    let t0 = std::time::Instant::now();
    let r = run_ea(&space, &pred, method, &cfg);
    println!(
        "# evaluated {} candidates in {:.2}s; frontier:",
        r.evaluated,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>8} {:>9} {:>10} {:>11}  mask (F=FuSe, d=depthwise)", "acc %", "lat ms", "MACs (M)", "params (M)");
    for c in &r.frontier {
        let mask: String = c.mask.iter().map(|&m| if m { 'F' } else { 'd' }).collect();
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}  {}",
            c.acc,
            c.latency_ms,
            c.macs as f64 / 1e6,
            c.params as f64 / 1e6,
            mask
        );
    }
    0
}

fn cmd_search_nas(argv: &[String]) -> i32 {
    let cli = Cli::new("search-nas", "OFA-space NAS")
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .opt("pop", "population", Some("32"))
        .opt("iters", "iterations", Some("16"))
        .opt("seed", "rng seed", Some("42"))
        .opt("threads", "worker threads (0=auto)", Some("0"))
        .flag("no-stos", "disable ST-OS")
        .flag("no-fuse", "search without the FuSe operator (baseline OFA)");
    let args = cli.parse(argv).unwrap();
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    let ev = std::sync::Arc::new(Evaluator::new(cfg));
    let cfg = NasConfig {
        population: args.usize("pop").unwrap(),
        iterations: args.usize("iters").unwrap(),
        seed: args.u64("seed").unwrap(),
        threads: args.usize("threads").unwrap(),
        allow_fuse: !args.flag("no-fuse"),
        ..NasConfig::default()
    };
    let t0 = std::time::Instant::now();
    let r = run_nas(ev, &cfg);
    println!(
        "# evaluated {} genomes in {:.2}s; frontier:",
        r.evaluated,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>8} {:>9} {:>10} {:>11}", "acc %", "lat ms", "MACs (M)", "params (M)");
    for c in &r.frontier {
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}",
            c.acc, c.latency_ms, c.macs_millions, c.params_millions
        );
    }
    0
}

/// `fuseconv search` — the streaming NAS job. Locally it runs the same
/// engine the server mounts (per-generation progress on stderr); with
/// `--remote` it sends a `search` request to a running `fuseconv serve`
/// or `fuseconv shard` and renders the v2 frame stream — `Progress` per
/// generation, live Pareto `search_row` frames (`--rows` to print
/// them), and the converged frontier from the terminal frame. The same
/// seed yields byte-identical frontiers locally and remotely.
fn cmd_search(argv: &[String]) -> i32 {
    use fuseconv::coordinator::search::SearchEvent;
    use fuseconv::coordinator::{ConfigPatch, SearchSpec};
    use fuseconv::exec::CancelToken;

    let cli = Cli::new("search", "streaming OFA NAS job, local or on a serving endpoint")
        .opt("pop", "population", Some("32"))
        .opt("iters", "iterations (generations)", Some("16"))
        .opt("mutation-p", "per-gene mutation probability", Some("0.15"))
        .opt("seed", "rng seed", Some("42"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .opt("threads", "local worker threads (0=auto; remote runs ignore this)", Some("0"))
        .opt("remote", "run on a `fuseconv serve`/`fuseconv shard` endpoint host:port", None)
        .opt("token", "auth token for an authenticated endpoint", None)
        .opt("id", "request id of the remote stream (the key `cancel` targets)", Some("21"))
        .opt("timeout-ms", "remote receive timeout", Some("600000"))
        .flag("http", "speak HTTP/SSE to the remote instead of TCP frames")
        .flag("rows", "print each streamed pareto row as it arrives (remote)")
        .flag("no-stos", "disable ST-OS")
        .flag("no-fuse", "search without the FuSe operator (baseline OFA)");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let (pop, iters, seed, threads, mutation_p) = match (
        args.usize("pop"),
        args.usize("iters"),
        args.u64("seed"),
        args.usize("threads"),
        args.f64("mutation-p"),
    ) {
        (Ok(p), Ok(i), Ok(s), Ok(t), Ok(m)) => (p, i, s, t, m),
        _ => {
            eprintln!("bad numeric option\n{}", cli.usage());
            return 2;
        }
    };
    let allow_fuse = !args.flag("no-fuse");

    if let Some(addr) = args.get("remote") {
        // --- remote: one `search` request, rendered from the stream ---
        let size = match args.usize("size") {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("{e}\n{}", cli.usage());
                return 2;
            }
        };
        let dataflow = match args.get("dataflow") {
            None => None,
            Some(df) => match Dataflow::parse(df) {
                Some(d) => Some(d),
                None => {
                    eprintln!("bad --dataflow {df:?} (want os|ws|is)\n{}", cli.usage());
                    return 2;
                }
            },
        };
        let spec = SearchSpec {
            population: pop,
            iterations: iters,
            mutation_p,
            allow_fuse,
            seed,
            config: ConfigPatch {
                size,
                dataflow,
                stos: if args.flag("no-stos") { Some(false) } else { None },
                ..ConfigPatch::default()
            },
        };
        let (id, timeout_ms) = match (args.u64("id"), args.u64("timeout-ms")) {
            (Ok(i), Ok(t)) => (i, t),
            _ => {
                eprintln!("bad numeric option\n{}", cli.usage());
                return 2;
            }
        };
        return search_remote(
            addr,
            spec,
            id,
            args.get("token"),
            std::time::Duration::from_millis(timeout_ms),
            args.flag("http"),
            args.flag("rows"),
        );
    }

    // --- local: same engine the server mounts, progress on stderr ---
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    let ev = std::sync::Arc::new(Evaluator::new(cfg));
    let nas = NasConfig {
        population: pop,
        iterations: iters,
        mutation_p,
        allow_fuse,
        seed,
        threads,
    };
    let t0 = std::time::Instant::now();
    let r = fuseconv::coordinator::search::run_nas_with(
        ev,
        &nas,
        None,
        &CancelToken::new(),
        |event| {
            let SearchEvent::Generation { done, total, front } = event;
            eprintln!(
                "# gen {done}/{total}: {} points on the front ({:.2}s)",
                front.len(),
                t0.elapsed().as_secs_f64()
            );
        },
    );
    eprintln!(
        "# evaluated {} genomes over {} generations in {:.2}s",
        r.evaluated,
        r.generations,
        t0.elapsed().as_secs_f64()
    );
    println!("{:>8} {:>9} {:>10} {:>11}  genome", "acc %", "lat ms", "MACs (M)", "params (M)");
    for c in &r.frontier {
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}  {}",
            c.acc,
            c.latency_ms,
            c.macs_millions,
            c.params_millions,
            c.genome.compact()
        );
    }
    0
}

/// The `--remote` leg of `fuseconv search`: send one `search` request
/// (TCP frames by default, HTTP/SSE with `--http`) and render its
/// stream. Progress goes to stderr; the terminal frontier prints as a
/// table on stdout.
fn search_remote(
    addr: &str,
    spec: fuseconv::coordinator::SearchSpec,
    id: u64,
    token: Option<&str>,
    timeout: std::time::Duration,
    http: bool,
    rows: bool,
) -> i32 {
    use fuseconv::coordinator::wire::encode_request_body;
    use fuseconv::coordinator::{
        http_sse_auth, Frame, Reply, Request, RequestBody, SearchReply, WireClient,
    };

    let t0 = std::time::Instant::now();
    let mut streamed = 0usize;
    let print_point = |p: &fuseconv::coordinator::SearchPoint| {
        println!(
            "row acc={:.2} lat_ms={:.3} macs_m={:.1} params_m={:.2} genome={}",
            p.acc, p.latency_ms, p.macs_m, p.params_m, p.genome
        );
    };
    let reply: Result<SearchReply, i32> = if http {
        let mut req = Request::new(id, RequestBody::Search { spec });
        if let Some(tok) = token {
            req = req.with_token(tok);
        }
        let result = http_sse_auth(
            addr,
            "/v1/search",
            &encode_request_body(&req),
            None,
            token,
            timeout,
            |_fid, frame| match frame {
                Frame::Progress { done, total } => {
                    eprintln!("# gen {done}/{total} ({:.2}s)", t0.elapsed().as_secs_f64());
                }
                Frame::SearchRow(p) => {
                    streamed += 1;
                    if rows {
                        print_point(p);
                    }
                }
                Frame::Row(_) | Frame::Final(_) => {}
            },
        );
        match result {
            Ok(resp) => match resp.result {
                Ok(Reply::Search(r)) => Ok(r),
                Ok(_) => {
                    eprintln!("remote answered search with a non-search reply");
                    Err(1)
                }
                Err(e) => {
                    eprintln!("remote search failed: {e}");
                    Err(1)
                }
            },
            Err(e) => {
                eprintln!("{e}");
                Err(1)
            }
        }
    } else {
        let mut req = Request::new(id, RequestBody::Search { spec });
        if let Some(tok) = token {
            req = req.with_token(tok);
        }
        let mut client = match WireClient::connect(addr, timeout) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("connect {addr}: {e}");
                return 1;
            }
        };
        if let Err(e) = client.send(&req) {
            eprintln!("send: {e}");
            return 1;
        }
        loop {
            match client.recv_frame(req.id) {
                Ok(Frame::Progress { done, total }) => {
                    eprintln!("# gen {done}/{total} ({:.2}s)", t0.elapsed().as_secs_f64());
                }
                Ok(Frame::SearchRow(p)) => {
                    streamed += 1;
                    if rows {
                        print_point(&p);
                    }
                }
                Ok(Frame::Row(_)) => {}
                Ok(Frame::Final(Ok(Reply::Search(r)))) => break Ok(r),
                Ok(Frame::Final(Ok(_))) => {
                    eprintln!("remote answered search with a non-search reply");
                    break Err(1);
                }
                Ok(Frame::Final(Err(e))) => {
                    eprintln!("remote search failed: {e}");
                    break Err(1);
                }
                Err(e) => {
                    eprintln!("{e}");
                    break Err(1);
                }
            }
        }
    };
    let r = match reply {
        Ok(r) => r,
        Err(code) => return code,
    };
    eprintln!(
        "# evaluated {} genomes over {} generations in {:.2}s \
         ({streamed} pareto rows streamed{})",
        r.evaluated,
        r.generations,
        t0.elapsed().as_secs_f64(),
        if r.cancelled { "; CANCELLED early" } else { "" },
    );
    println!("{:>8} {:>9} {:>10} {:>11}  genome", "acc %", "lat ms", "MACs (M)", "params (M)");
    for p in &r.frontier {
        println!(
            "{:>8.2} {:>9.3} {:>10.1} {:>11.2}  {}",
            p.acc, p.latency_ms, p.macs_m, p.params_m, p.genome
        );
    }
    0
}

fn cmd_trace(argv: &[String]) -> i32 {
    let cli = Cli::new("trace", "cycle-trace one layer")
        .opt("model", "zoo network", Some("mobilenet-v2"))
        .opt("size", "array dimension", Some("16"))
        .opt("dataflow", "os|ws|is", Some("os"))
        .opt("layer", "layer index", Some("1"))
        .opt("windows", "max trace windows", Some("64"))
        .flag("no-stos", "disable ST-OS")
        .flag("fuse", "FuSe-Half transform first");
    let args = cli.parse(argv).unwrap();
    let Some(mut net) = models::by_name(&args.str("model")) else {
        eprintln!("unknown model");
        return 2;
    };
    if args.flag("fuse") {
        net = fuse_all(&net, Variant::Half);
    }
    let idx = args.usize("layer").unwrap();
    if idx >= net.layers.len() {
        eprintln!("layer {idx} out of range ({} layers)", net.layers.len());
        return 2;
    }
    let Some(cfg) = sim_config_or_usage(&args, &cli) else {
        return 2;
    };
    let fs = fuseconv::sim::engine::schedule_layer(&net.layers[idx], &cfg);
    let trace = fuseconv::sim::trace::expand(&fs, args.usize("windows").unwrap());
    print!("# {} / {}\n{}", net.name, net.layers[idx].name, fuseconv::sim::trace::to_csv(&trace));
    0
}

#[cfg(not(feature = "xla"))]
fn cmd_train(_argv: &[String]) -> i32 {
    eprintln!("`train` needs the PJRT runtime; rebuild with `--features xla`");
    1
}

/// `fuseconv serve --listen addr` — the serving frontends. Simulation
/// traffic always works; inference traffic needs an engine (`mock` by
/// default, `pjrt` with `--features xla`, `none` to reject it). With
/// `--http-port` an HTTP/SSE listener runs alongside the TCP one on the
/// same `Router`, so `curl` and dashboards share the caches, lanes, and
/// shutdown latch with wire clients.
fn cmd_serve(argv: &[String]) -> i32 {
    use fuseconv::coordinator::batcher::BatchPolicy;
    use fuseconv::coordinator::{Router, SimServer};

    let cli = Cli::new("serve", "TCP + HTTP serving frontends for inference + simulation")
        .opt("listen", "bind address (port 0 = ephemeral)", Some("127.0.0.1:7878"))
        .opt("http-port", "also serve HTTP/SSE on this port, same host (0 = ephemeral)", None)
        .opt("http-port-file", "write the bound HTTP address here once listening", None)
        .opt("threads", "simulation worker threads (0=auto)", Some("0"))
        .opt("sim-capacity", "interactive simulation admission lane bound (min 1)", Some("256"))
        .opt("batch-capacity", "batch (sweep) admission lane bound (min 1)", Some("32"))
        .opt("search-capacity", "search admission lane bound (min 1)", Some("4"))
        .opt("auth-token", "require this token on every request (TCP envelope / HTTP bearer)", None)
        .opt("cache-entries", "global result cache size (entries; 0 = off)", Some("0"))
        .opt("max-requests-per-conn", "per-connection request budget (0=unlimited)", Some("0"))
        .opt("queue", "bounded inference admission queue", Some("1024"))
        .opt("engine", "inference engine: mock | none | pjrt", Some("mock"))
        .opt("engine-input", "mock engine input length", Some("4"))
        .opt("engine-output", "mock engine output length", Some("2"))
        .opt("max-batch", "dynamic batch cap", Some("8"))
        .opt("max-wait-ms", "batch deadline (ms)", Some("2"))
        .opt("port-file", "write the bound address here once listening", None)
        .opt("artifacts", "artifacts dir (pjrt engine only)", Some("artifacts"))
        .opt("transport", "connection concurrency: threaded | epoll", Some("threaded"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let (threads, sim_capacity, batch_capacity, conn_budget, queue, max_batch, max_wait) =
        match (
            args.usize("threads"),
            args.usize("sim-capacity"),
            args.usize("batch-capacity"),
            args.u64("max-requests-per-conn"),
            args.usize("queue"),
            args.usize("max-batch"),
            args.u64("max-wait-ms"),
        ) {
            (Ok(t), Ok(sc), Ok(bc), Ok(rb), Ok(q), Ok(mb), Ok(mw)) => {
                (t, sc, bc, rb, q, mb, mw)
            }
            _ => {
                eprintln!("bad numeric option\n{}", cli.usage());
                return 2;
            }
        };
    let search_capacity = match args.usize("search-capacity") {
        Ok(sc) => sc,
        Err(_) => {
            eprintln!("bad numeric option\n{}", cli.usage());
            return 2;
        }
    };
    let cache_entries = match args.usize("cache-entries") {
        Ok(ce) => ce,
        Err(_) => {
            eprintln!("bad numeric option\n{}", cli.usage());
            return 2;
        }
    };
    let mut sim = SimServer::with_lanes(
        threads,
        std::sync::Arc::new(LayerCache::new()),
        sim_capacity,
        batch_capacity,
    )
    .with_search_capacity(search_capacity);
    if cache_entries > 0 {
        sim = sim.with_result_cache(std::sync::Arc::new(ResultCache::new(cache_entries)));
    }
    let policy = BatchPolicy {
        max_batch,
        max_wait: std::time::Duration::from_millis(max_wait),
    };
    let router = match args.str("engine").as_str() {
        "none" => Router::new(sim),
        "mock" => {
            use fuseconv::coordinator::{MockEngine, Server};
            let (in_len, out_len) = match (args.usize("engine-input"), args.usize("engine-output"))
            {
                (Ok(i), Ok(o)) if i > 0 && o > 0 => (i, o),
                _ => {
                    eprintln!("bad --engine-input/--engine-output\n{}", cli.usage());
                    return 2;
                }
            };
            let max_b = max_batch.max(1);
            Router::new(sim).with_engine(Server::start_with_queue(
                move || MockEngine::new(in_len, out_len, max_b),
                policy,
                queue,
            ))
        }
        "pjrt" => match pjrt_router(sim, policy, queue, &args.str("artifacts")) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        },
        other => {
            eprintln!("unknown --engine {other:?} (want mock|none|pjrt)\n{}", cli.usage());
            return 2;
        }
    };

    let http_port = match args.opt_u64("http-port") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let Some(transport) = fuseconv::coordinator::Transport::parse(&args.str("transport")) else {
        eprintln!(
            "unknown --transport {:?} (want threaded|epoll)\n{}",
            args.str("transport"),
            cli.usage()
        );
        return 2;
    };

    // One set of live gauges shared by both listeners, reported through
    // the service's stats reply.
    let gauges = fuseconv::coordinator::TransportGauges::new();
    let router = router.with_gauges(gauges.clone());
    let listen = args.str("listen");
    run_frontends(
        std::sync::Arc::new(router),
        &FrontendOpts {
            listen: &listen,
            http_port,
            budget: (conn_budget > 0).then_some(conn_budget),
            port_file: args.get("port-file"),
            http_port_file: args.get("http-port-file"),
            label: "serve",
            transport,
            gauges,
            auth_token: args.get("auth-token"),
        },
    )
}

/// Everything `run_frontends` needs besides the service itself.
struct FrontendOpts<'a> {
    /// TCP bind address (port 0 = ephemeral).
    listen: &'a str,
    /// Also run an HTTP/SSE listener on this port (same host).
    http_port: Option<u64>,
    /// Per-connection request budget (both transports).
    budget: Option<u64>,
    port_file: Option<&'a str>,
    http_port_file: Option<&'a str>,
    /// Subcommand name for banner lines (`serve` / `shard`).
    label: &'a str,
    /// Concurrency model for both listeners.
    transport: fuseconv::coordinator::Transport,
    /// Live gauges shared by both listeners (and the mounted service's
    /// stats reply, via `with_gauges` on the router).
    gauges: fuseconv::coordinator::TransportGauges,
    /// Require this token on every request, both transports (TCP
    /// `token` envelope field / HTTP `Authorization: Bearer`).
    auth_token: Option<&'a str>,
}

/// Mount one service on the wire frontends: the TCP listener always,
/// plus an HTTP/SSE listener when requested — both sharing one
/// `StopLatch`, so a `Shutdown` served by either transport stops
/// both. Shared by `fuseconv serve` (single node) and `fuseconv shard`
/// (front tier): the frontends mount any `Service` unchanged.
fn run_frontends(
    service: std::sync::Arc<dyn fuseconv::coordinator::Service>,
    opts: &FrontendOpts<'_>,
) -> i32 {
    use fuseconv::coordinator::{HttpServer, StopLatch, WireServer, PROTOCOL_VERSION};

    let stop = StopLatch::new();
    let label = opts.label;
    let wire = match WireServer::bind(opts.listen, std::sync::Arc::clone(&service)) {
        Ok(w) => w
            .with_request_budget(opts.budget)
            .with_stop(stop.clone())
            .with_transport(opts.transport)
            .with_gauges(opts.gauges.clone())
            .with_auth_token(opts.auth_token.map(str::to_string)),
        Err(e) => {
            eprintln!("bind {}: {e}", opts.listen);
            return 1;
        }
    };
    let addr = wire.local_addr();
    eprintln!(
        "fuseconv {label}: listening on {addr} (protocol v{PROTOCOL_VERSION}); \
         send {{\"op\":\"shutdown\"}} to stop"
    );
    if let Some(path) = opts.port_file {
        if let Err(e) = std::fs::write(path, addr.to_string()) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
    }

    // Optional HTTP/SSE listener on the same host, service, and latch:
    // a shutdown served by either transport stops both.
    let mut http_thread = None;
    if let Some(port) = opts.http_port {
        let host = opts.listen.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
        let http_listen = format!("{host}:{port}");
        let http = match HttpServer::bind(&http_listen, std::sync::Arc::clone(&service)) {
            Ok(h) => h
                .with_request_budget(opts.budget)
                .with_stop(stop.clone())
                .with_transport(opts.transport)
                .with_gauges(opts.gauges.clone())
                .with_auth_token(opts.auth_token.map(str::to_string)),
            Err(e) => {
                eprintln!("bind {http_listen}: {e}");
                return 1;
            }
        };
        let http_addr = http.local_addr();
        eprintln!(
            "fuseconv {label}: http on {http_addr} \
             (POST /v1/{{infer,simulate,cancel}}, POST /v1/{{sweep,search}} stream SSE, \
             GET /v1/stats, GET /healthz)"
        );
        if let Some(path) = opts.http_port_file {
            if let Err(e) = std::fs::write(path, http_addr.to_string()) {
                eprintln!("writing {path}: {e}");
                return 1;
            }
        }
        http_thread = Some(std::thread::spawn(move || http.run()));
    }

    let code = match wire.run() {
        Ok(()) => {
            eprintln!("fuseconv {label}: clean shutdown");
            0
        }
        Err(e) => {
            eprintln!("{label} failed: {e}");
            1
        }
    };
    if let Some(h) = http_thread {
        // The latch has tripped (or the TCP listener failed): release
        // and join the HTTP listener too before exiting.
        stop.trip();
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                eprintln!("http {label} failed: {e}");
                return 1;
            }
            Err(_) => {
                eprintln!("http {label} panicked");
                return 1;
            }
        }
    }
    code
}

/// `fuseconv shard --backends addr1,addr2,...` — the multi-node front
/// tier: partitions `Simulate` traffic across backends by a stable
/// (model, config) hash so each backend's layer cache stays hot on its
/// shard, splits `Sweep` grids into per-backend sub-plans and merges
/// the row streams back into plan order, aggregates `Stats`, and fans
/// `Shutdown` out to the whole deployment. The fleet self-heals: health
/// probes (`--probe-interval-ms`, `--probe-failures`) take dead
/// backends out of routing, sweeps re-steer a dead backend's remaining
/// cells onto survivors mid-stream, and membership changes at runtime
/// via the `add-backend` / `drain-backend` admin ops. Mounts the same
/// TCP and HTTP/SSE frontends as `fuseconv serve`.
fn cmd_shard(argv: &[String]) -> i32 {
    use fuseconv::coordinator::ShardRouter;

    let cli = Cli::new("shard", "shard-router front tier over several `fuseconv serve` backends")
        .opt("backends", "comma list of backend addresses host:port (required)", None)
        .opt("listen", "bind address (port 0 = ephemeral)", Some("127.0.0.1:7900"))
        .opt("http-port", "also serve HTTP/SSE on this port, same host (0 = ephemeral)", None)
        .opt("http-port-file", "write the bound HTTP address here once listening", None)
        .opt("max-requests-per-conn", "per-connection request budget (0=unlimited)", Some("0"))
        .opt("max-inflight", "front-tier in-flight request bound (min 1)", Some("1024"))
        .opt("timeout-ms", "backend connect/receive timeout (0 = none)", Some("600000"))
        .opt("probe-interval-ms", "backend health-probe cadence (0 = disabled)", Some("1000"))
        .opt("probe-failures", "consecutive probe failures before a backend is Down", Some("3"))
        .opt("auth-token", "require this token on every request (TCP envelope / HTTP bearer)", None)
        .opt("port-file", "write the bound address here once listening", None)
        .opt("transport", "connection concurrency: threaded | epoll", Some("threaded"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };
    let backends: Vec<String> = args
        .get("backends")
        .unwrap_or("")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if backends.is_empty() {
        eprintln!("--backends needs at least one host:port address\n{}", cli.usage());
        return 2;
    }
    let (conn_budget, max_inflight, timeout_ms, probe_ms, probe_failures) = match (
        args.u64("max-requests-per-conn"),
        args.usize("max-inflight"),
        args.u64("timeout-ms"),
        args.u64("probe-interval-ms"),
        args.u64("probe-failures"),
    ) {
        (Ok(rb), Ok(mi), Ok(t), Ok(p), Ok(pf)) => (rb, mi, t, p, pf),
        _ => {
            eprintln!("bad numeric option\n{}", cli.usage());
            return 2;
        }
    };
    let http_port = match args.opt_u64("http-port") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };

    let Some(transport) = fuseconv::coordinator::Transport::parse(&args.str("transport")) else {
        eprintln!(
            "unknown --transport {:?} (want threaded|epoll)\n{}",
            args.str("transport"),
            cli.usage()
        );
        return 2;
    };

    let timeout = std::time::Duration::from_millis(timeout_ms);
    let gauges = fuseconv::coordinator::TransportGauges::new();
    let router = ShardRouter::new(backends.clone(), timeout)
        .with_inflight(max_inflight)
        .with_gauges(gauges.clone())
        .with_probes(
            std::time::Duration::from_millis(probe_ms),
            probe_failures.max(1) as u32,
        );
    eprintln!(
        "fuseconv shard: fronting {} backend(s): {}",
        backends.len(),
        backends.join(", ")
    );
    let listen = args.str("listen");
    run_frontends(
        std::sync::Arc::new(router),
        &FrontendOpts {
            listen: &listen,
            http_port,
            budget: (conn_budget > 0).then_some(conn_budget),
            port_file: args.get("port-file"),
            http_port_file: args.get("http-port-file"),
            label: "shard",
            transport,
            gauges,
            auth_token: args.get("auth-token"),
        },
    )
}

#[cfg(feature = "xla")]
fn pjrt_router(
    sim: fuseconv::coordinator::SimServer,
    policy: fuseconv::coordinator::batcher::BatchPolicy,
    queue: usize,
    artifacts: &str,
) -> Result<fuseconv::coordinator::Router, String> {
    use fuseconv::coordinator::{Router, Server};
    let dir = std::path::PathBuf::from(artifacts);
    if !dir.join("manifest.txt").exists() {
        return Err("artifacts not built; run `make artifacts`".into());
    }
    Ok(Router::new(sim).with_engine(Server::start_with_queue(
        move || fuseconv::runtime::PjrtEngine::from_artifacts(&dir, "student_init.bin").unwrap(),
        policy,
        queue,
    )))
}

#[cfg(not(feature = "xla"))]
fn pjrt_router(
    _sim: fuseconv::coordinator::SimServer,
    _policy: fuseconv::coordinator::batcher::BatchPolicy,
    _queue: usize,
    _artifacts: &str,
) -> Result<fuseconv::coordinator::Router, String> {
    Err("--engine pjrt needs the PJRT runtime; rebuild with `--features xla`".into())
}

/// `fuseconv request` — wire client for a running `fuseconv serve`
/// (scripted load: `--count N` pipelines N copies on one connection;
/// `--stream` prints every protocol frame as it arrives instead of the
/// collapsed one-line response).
fn cmd_request(argv: &[String]) -> i32 {
    use fuseconv::coordinator::wire::{encode_frame, encode_response};
    use fuseconv::coordinator::{
        ConfigPatch, Frame, ModelSpec, Request, RequestBody, WireClient,
    };

    let cli = Cli::new("request", "send protocol requests to a running `fuseconv serve`")
        .opt("connect", "server address host:port", Some("127.0.0.1:7878"))
        .opt(
            "op",
            "infer | simulate | sweep | stats | zoo | cancel | add-backend | drain-backend | shutdown",
            Some("simulate"),
        )
        .opt("token", "auth token for an authenticated server", None)
        .opt("backend", "backend host:port (add-backend / drain-backend, shard front tier)", None)
        .opt("model", "zoo model (simulate)", Some("mobilenet-v2"))
        .opt("model-file", "inline ModelSpec JSON file (simulate; overrides --model)", None)
        .opt("models", "comma list of zoo models (sweep)", Some("mobilenet-v2"))
        .opt("variant", "base|half|full (simulate)", Some("base"))
        .opt("variants", "comma list of variants (sweep)", Some("base,half"))
        .opt("size", "square array size override", None)
        .opt("sizes", "comma list of array sizes (sweep)", Some("8,16"))
        .opt("dataflow", "os|ws|is override", None)
        .opt("dataflows", "comma list of os,ws,is (sweep grid axis; overrides --dataflow)", None)
        .opt("input", "comma-separated floats (infer)", Some("0,0,0,0"))
        .opt("count", "repeat the request N times on one connection", Some("1"))
        .opt("deadline-ms", "per-request deadline", None)
        .opt("timeout-ms", "client receive timeout", Some("60000"))
        .opt("id", "starting request id", Some("1"))
        .flag("stream", "print every frame (progress/row/final) as it arrives")
        .flag("http", "speak HTTP to the server (ops map to /v1/<op>, sweep streams SSE)")
        .flag("no-stos", "disable ST-OS in the request config");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{}", cli.usage());
            return 2;
        }
    };

    // shared config overrides (simulate + sweep)
    let patch = {
        let size = match args.opt_usize("size") {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}\n{}", cli.usage());
                return 2;
            }
        };
        let dataflow = match args.get("dataflow") {
            None => None,
            Some(df) => match Dataflow::parse(df) {
                Some(d) => Some(d),
                None => {
                    eprintln!("bad --dataflow {df:?} (want os|ws|is)\n{}", cli.usage());
                    return 2;
                }
            },
        };
        ConfigPatch {
            size,
            dataflow,
            stos: if args.flag("no-stos") { Some(false) } else { None },
            ..ConfigPatch::default()
        }
    };

    let body = match args.str("op").as_str() {
        "infer" => {
            let mut input = Vec::new();
            for tok in args.str("input").split(',').filter(|s| !s.is_empty()) {
                match tok.trim().parse::<f32>() {
                    Ok(x) => input.push(x),
                    Err(_) => {
                        eprintln!("bad --input element {tok:?}");
                        return 2;
                    }
                }
            }
            RequestBody::Infer { input }
        }
        "simulate" => {
            let Some(variant) = FuseVariant::parse(&args.str("variant")) else {
                eprintln!("bad --variant (want base|half|full)\n{}", cli.usage());
                return 2;
            };
            // `--model-file spec.json` sends an *inline* ModelSpec — the
            // full layer list travels in the request, so non-zoo networks
            // (including dilated/transposed/grouped layers) can be
            // simulated without teaching the server their names.
            let model = match args.get("model-file") {
                Some(path) => {
                    let text = match std::fs::read_to_string(path) {
                        Ok(t) => t,
                        Err(e) => {
                            eprintln!("reading {path}: {e}");
                            return 2;
                        }
                    };
                    match fuseconv::coordinator::wire::model_spec_from_json_str(&text) {
                        Ok(spec) => spec,
                        Err(e) => {
                            eprintln!("{path}: {e}");
                            return 2;
                        }
                    }
                }
                None => ModelSpec::Zoo(args.str("model")),
            };
            RequestBody::Simulate { model, variant, config: patch }
        }
        "sweep" => {
            let models: Vec<String> = args
                .str("models")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            let mut variants = Vec::new();
            for tok in args.str("variants").split(',').filter(|s| !s.is_empty()) {
                match FuseVariant::parse(tok) {
                    Some(v) => variants.push(v),
                    None => {
                        eprintln!("bad variant {tok:?} (want base|half|full)");
                        return 2;
                    }
                }
            }
            // `--dataflows os,ws,is` turns the dataflow into a grid
            // axis; the cross product is size-major, dataflow-minor —
            // the same plan order `grid_configs` produces locally.
            let dataflows: Vec<Option<Dataflow>> = match args.get("dataflows") {
                None => vec![None],
                Some(list) => {
                    let mut v = Vec::new();
                    for tok in list.split(',').filter(|s| !s.is_empty()) {
                        match Dataflow::parse(tok) {
                            Some(d) => v.push(Some(d)),
                            None => {
                                eprintln!("unknown dataflow {tok:?} (want os|ws|is)");
                                return 2;
                            }
                        }
                    }
                    v
                }
            };
            let mut configs = Vec::new();
            for tok in args.str("sizes").split(',').filter(|s| !s.is_empty()) {
                match tok.parse::<usize>() {
                    Ok(n) if n > 0 => {
                        for &df in &dataflows {
                            configs.push(ConfigPatch {
                                size: Some(n),
                                dataflow: df.or(patch.dataflow),
                                ..patch.clone()
                            })
                        }
                    }
                    _ => {
                        eprintln!("bad array size {tok:?}");
                        return 2;
                    }
                }
            }
            RequestBody::Sweep { models, variants, configs }
        }
        "stats" => RequestBody::Stats,
        "zoo" => RequestBody::Zoo,
        // `--op cancel --id N` targets the in-flight stream whose
        // request id is N (typically a `fuseconv search --remote --id N`
        // on another connection). Idempotent: unknown ids still ack.
        "cancel" => match args.u64("id") {
            Ok(target) => RequestBody::Cancel { target },
            Err(e) => {
                eprintln!("{e}\n{}", cli.usage());
                return 2;
            }
        },
        // Fleet membership (shard front tier only): `--op add-backend
        // --backend host:port` joins a node, `--op drain-backend` stops
        // routing new work to it and removes it once idle.
        "add-backend" | "drain-backend" => {
            let Some(addr) = args.get("backend").map(str::to_string) else {
                eprintln!("--op {} needs --backend host:port\n{}", args.str("op"), cli.usage());
                return 2;
            };
            if args.str("op") == "add-backend" {
                RequestBody::AddBackend { addr }
            } else {
                RequestBody::DrainBackend { addr }
            }
        }
        "shutdown" => RequestBody::Shutdown,
        other => {
            eprintln!("unknown --op {other:?}\n{}", cli.usage());
            return 2;
        }
    };

    let (count, base_id, timeout_ms, deadline_ms) = match (
        args.usize("count"),
        args.u64("id"),
        args.u64("timeout-ms"),
        args.opt_u64("deadline-ms"),
    ) {
        (Ok(c), Ok(i), Ok(t), Ok(d)) => (c.max(1), i, t, d),
        _ => {
            eprintln!("bad numeric option\n{}", cli.usage());
            return 2;
        }
    };

    let addr = args.str("connect");
    let timeout = std::time::Duration::from_millis(timeout_ms);
    if args.flag("http") {
        return run_http_requests(
            &addr,
            &body,
            count,
            base_id,
            deadline_ms,
            args.get("token"),
            timeout,
            args.flag("stream"),
        );
    }
    let mut client = match WireClient::connect(&addr, timeout) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    // pipeline all requests, then collect every reply stream
    for i in 0..count {
        let mut req = Request::new(base_id + i as u64, body.clone());
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        if let Some(tok) = args.get("token") {
            req = req.with_token(tok);
        }
        if let Err(e) = client.send(&req) {
            eprintln!("send: {e}");
            return 1;
        }
    }
    let mut failures = 0usize;
    if args.flag("stream") {
        // raw frame view: print progress/row/final frames as they arrive,
        // interleaved across the pipelined requests, until every stream
        // has delivered its terminal frame
        let mut outstanding: std::collections::HashSet<u64> =
            (0..count).map(|i| base_id + i as u64).collect();
        while !outstanding.is_empty() {
            match client.recv_any() {
                Ok((id, frame)) => {
                    println!("{}", encode_frame(id, &frame));
                    if let Frame::Final(result) = &frame {
                        outstanding.remove(&id);
                        if result.is_err() {
                            failures += 1;
                        }
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        }
    } else {
        // collapsed view: one line per request (streamed sweep rows are
        // merged back into a single `sweep` reply)
        for i in 0..count {
            match client.recv_response(base_id + i as u64) {
                Ok(resp) => {
                    println!("{}", encode_response(&resp));
                    if !resp.is_ok() {
                        failures += 1;
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        }
    }
    if failures > 0 {
        eprintln!("# {failures}/{count} requests failed");
        1
    } else {
        0
    }
}

/// The `--http` transport of `fuseconv request`: one-shot ops go
/// through `http_call` (GET for stats/zoo, POST otherwise), sweeps
/// stream over SSE via `http_sse`. `--stream` prints each frame as it
/// arrives (`data:` JSON is identical to the TCP framing); otherwise
/// one collapsed response prints per request.
#[allow(clippy::too_many_arguments)]
fn run_http_requests(
    addr: &str,
    body: &fuseconv::coordinator::RequestBody,
    count: usize,
    base_id: u64,
    deadline_ms: Option<u64>,
    token: Option<&str>,
    timeout: std::time::Duration,
    stream: bool,
) -> i32 {
    use fuseconv::coordinator::wire::{encode_frame, encode_request_body, encode_response};
    use fuseconv::coordinator::{http_call_auth, http_sse_auth, Request, RequestBody};

    let mut failures = 0usize;
    for i in 0..count {
        let mut req = Request::new(base_id + i as u64, body.clone());
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        // POST bodies carry deadline_ms already; also send the
        // timeout-ms header so body-less GET ops (stats/zoo) get the
        // same deadline semantics as the TCP transport. Auth rides the
        // `authorization: Bearer` header, never the body.
        let result = match &req.body {
            RequestBody::Sweep { .. } | RequestBody::Search { .. } => {
                let path = if matches!(req.body, RequestBody::Sweep { .. }) {
                    "/v1/sweep"
                } else {
                    "/v1/search"
                };
                http_sse_auth(
                    addr,
                    path,
                    &encode_request_body(&req),
                    deadline_ms,
                    token,
                    timeout,
                    |fid, frame| {
                        if stream {
                            println!("{}", encode_frame(fid, frame));
                        }
                    },
                )
                .map(|resp| (resp, stream))
            }
            _ => {
                let (path, payload) = match &req.body {
                    RequestBody::Stats => ("/v1/stats", None),
                    RequestBody::Zoo => ("/v1/zoo", None),
                    RequestBody::Shutdown => ("/v1/shutdown", Some(encode_request_body(&req))),
                    RequestBody::Infer { .. } => ("/v1/infer", Some(encode_request_body(&req))),
                    RequestBody::Simulate { .. } => {
                        ("/v1/simulate", Some(encode_request_body(&req)))
                    }
                    RequestBody::Cancel { .. } => ("/v1/cancel", Some(encode_request_body(&req))),
                    RequestBody::AddBackend { .. } => {
                        ("/v1/add-backend", Some(encode_request_body(&req)))
                    }
                    RequestBody::DrainBackend { .. } => {
                        ("/v1/drain-backend", Some(encode_request_body(&req)))
                    }
                    RequestBody::Sweep { .. } | RequestBody::Search { .. } => {
                        unreachable!("handled above")
                    }
                };
                http_call_auth(addr, path, payload.as_deref(), deadline_ms, token, timeout)
                    .and_then(|reply| reply.response())
                    .map(|resp| (resp, false))
            }
        };
        match result {
            Ok((resp, already_printed)) => {
                // streamed sweeps printed their frames (final included)
                if !already_printed {
                    println!("{}", encode_response(&resp));
                }
                if !resp.is_ok() {
                    failures += 1;
                }
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    }
    if failures > 0 {
        eprintln!("# {failures}/{count} requests failed");
        1
    } else {
        0
    }
}

#[cfg(feature = "xla")]
fn cmd_train(argv: &[String]) -> i32 {
    let cli = Cli::new("train", "end-to-end NOS pipeline on AOT artifacts")
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("steps", "training steps per phase", Some("150"))
        .opt("lr", "initial learning rate", Some("0.06"))
        .opt("seed", "data seed", Some("17"))
        .opt("eval", "eval samples", Some("256"));
    let args = cli.parse(argv).unwrap();
    match fuseconv::runtime::pipeline::run_nos_pipeline(
        &args.str("artifacts"),
        args.usize("steps").unwrap(),
        args.f64("lr").unwrap() as f32,
        args.u64("seed").unwrap(),
        args.usize("eval").unwrap(),
        true,
    ) {
        Ok(_) => 0,
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

