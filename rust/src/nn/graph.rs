//! Network = named, ordered list of layers plus block structure.
//!
//! The builder tracks the "cursor" (current spatial dims + channels) so model
//! definitions read like the tables in the MobileNet/MnasNet papers, and
//! mistakes in chaining (channel mismatches) fail loudly at build time.

use super::layer::Layer;
use super::ops::{Act, OpClass, OpKind};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Number of mobile-bottleneck blocks (contiguous `block` ids).
    pub num_blocks: usize,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn macs_millions(&self) -> f64 {
        self.total_macs() as f64 / 1e6
    }

    pub fn params_millions(&self) -> f64 {
        self.total_params() as f64 / 1e6
    }

    /// MACs per operator class (Fig 9a attribution).
    pub fn macs_by_class(&self) -> BTreeMap<OpClass, u64> {
        let mut m = BTreeMap::new();
        for l in &self.layers {
            *m.entry(l.class()).or_insert(0) += l.macs();
        }
        m
    }

    /// Layers of a given bottleneck block.
    pub fn block_layers(&self, b: usize) -> Vec<&Layer> {
        self.layers.iter().filter(|l| l.block == Some(b)).collect()
    }

    /// Indices of blocks that contain a depthwise or FuSe op (i.e. the
    /// replaceable mobile-bottleneck blocks of the paper's search space).
    pub fn bottleneck_blocks(&self) -> Vec<usize> {
        (0..self.num_blocks)
            .filter(|&b| {
                self.layers.iter().any(|l| {
                    l.block == Some(b)
                        && matches!(l.class(), OpClass::Depthwise | OpClass::FuSe)
                })
            })
            .collect()
    }
}

/// Builder that threads spatial dims + channels through the definition.
pub struct NetBuilder {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<Layer>,
    block: Option<usize>,
    next_block: usize,
}

impl NetBuilder {
    pub fn new(name: impl Into<String>, input_hw: usize, input_c: usize) -> NetBuilder {
        NetBuilder {
            name: name.into(),
            h: input_hw,
            w: input_hw,
            c: input_c,
            layers: Vec::new(),
            block: None,
            next_block: 0,
        }
    }

    pub fn cursor(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    fn push(&mut self, name: String, op: OpKind, act: Act) -> &mut Self {
        assert_eq!(
            op.cin(),
            self.c,
            "{}: layer {} expects cin={} but cursor has {} channels",
            self.name,
            name,
            op.cin(),
            self.c
        );
        let mut l = Layer::new(name, op, self.h, self.w).with_act(act);
        l.block = self.block;
        self.h = l.out_h();
        self.w = l.out_w();
        self.c = l.out_c();
        self.layers.push(l);
        self
    }

    /// Begin a mobile-bottleneck block; layers added until `end_block` share
    /// the block id.
    pub fn begin_block(&mut self) -> usize {
        let b = self.next_block;
        self.block = Some(b);
        self.next_block += 1;
        b
    }

    pub fn end_block(&mut self) {
        self.block = None;
    }

    pub fn conv(&mut self, name: &str, k: usize, stride: usize, cout: usize, act: Act) -> &mut Self {
        let cin = self.c;
        self.push(name.into(), OpKind::Conv2d { k, stride, cin, cout }, act)
    }

    pub fn dw(&mut self, name: &str, k: usize, stride: usize, act: Act) -> &mut Self {
        let c = self.c;
        self.push(name.into(), OpKind::Depthwise { k, stride, c }, act)
    }

    pub fn pw(&mut self, name: &str, cout: usize, act: Act) -> &mut Self {
        let cin = self.c;
        self.push(name.into(), OpKind::Pointwise { cin, cout }, act)
    }

    /// FuSe pair (row+col). `full`: both orientations over all channels
    /// (output 2C); otherwise Half (C/2 + C/2, output C). Emitted as two
    /// layers that the simulator schedules independently; the *cursor*
    /// channel count after the pair is 2C (Full) or C (Half).
    pub fn fuse(&mut self, name: &str, k: usize, stride: usize, full: bool, act: Act) -> &mut Self {
        let c = self.c;
        if full {
            let row = OpKind::FuseRow { k, stride, c };
            let col = OpKind::FuseCol { k, stride, c };
            // Row half:
            let mut l = Layer::new(format!("{name}.row"), row, self.h, self.w).with_act(act);
            l.block = self.block;
            self.layers.push(l);
            let mut l = Layer::new(format!("{name}.col"), col, self.h, self.w).with_act(act);
            l.block = self.block;
            // advance cursor once (both see the same input)
            self.h = l.out_h();
            self.w = l.out_w();
            self.c = 2 * c;
            self.layers.push(l);
        } else {
            assert!(c % 2 == 0, "FuSe-Half requires even channels, got {c}");
            let row = OpKind::FuseRow { k, stride, c: c / 2 };
            let col = OpKind::FuseCol { k, stride, c: c / 2 };
            let mut l = Layer::new(format!("{name}.row"), row, self.h, self.w).with_act(act);
            l.block = self.block;
            self.layers.push(l);
            let mut l = Layer::new(format!("{name}.col"), col, self.h, self.w).with_act(act);
            l.block = self.block;
            self.h = l.out_h();
            self.w = l.out_w();
            self.c = c;
            self.layers.push(l);
        }
        self
    }

    /// Dilated `k×k` conv at the given rate (DeepLab/ESPNet-style context
    /// aggregation without spatial downsampling).
    pub fn dilated(
        &mut self,
        name: &str,
        k: usize,
        stride: usize,
        dilation: usize,
        cout: usize,
        act: Act,
    ) -> &mut Self {
        assert!(dilation >= 1, "{name}: dilation must be >= 1");
        let cin = self.c;
        self.push(name.into(), OpKind::Dilated { k, stride, dilation, cin, cout }, act)
    }

    /// Transposed conv: upsamples the cursor by `stride` (decoder stages).
    pub fn tconv(&mut self, name: &str, k: usize, stride: usize, cout: usize, act: Act) -> &mut Self {
        let cin = self.c;
        self.push(name.into(), OpKind::Transposed { k, stride, cin, cout }, act)
    }

    /// Grouped `k×k` conv; `groups` must divide both cin and cout.
    pub fn gconv(
        &mut self,
        name: &str,
        k: usize,
        stride: usize,
        groups: usize,
        cout: usize,
        act: Act,
    ) -> &mut Self {
        let cin = self.c;
        assert!(
            groups >= 1 && cin % groups == 0 && cout % groups == 0,
            "{name}: groups={groups} must divide cin={cin} and cout={cout}"
        );
        self.push(name.into(), OpKind::Grouped { k, stride, groups, cin, cout }, act)
    }

    pub fn se(&mut self, name: &str, reduced: usize) -> &mut Self {
        let c = self.c;
        self.push(name.into(), OpKind::SqueezeExcite { c, reduced }, Act::HSigmoid)
    }

    pub fn add(&mut self, name: &str) -> &mut Self {
        let c = self.c;
        self.push(name.into(), OpKind::Add { c }, Act::None)
    }

    pub fn global_pool(&mut self, name: &str) -> &mut Self {
        let c = self.c;
        self.push(name.into(), OpKind::GlobalPool { c }, Act::None)
    }

    pub fn fc(&mut self, name: &str, cout: usize, act: Act) -> &mut Self {
        let cin = self.c;
        self.push(name.into(), OpKind::Fc { cin, cout }, act)
    }

    pub fn build(&mut self) -> Network {
        Network {
            name: std::mem::take(&mut self.name),
            layers: std::mem::take(&mut self.layers),
            num_blocks: self.next_block,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_threads_shapes() {
        let mut b = NetBuilder::new("t", 32, 3);
        b.conv("stem", 3, 2, 8, Act::Relu);
        assert_eq!(b.cursor(), (16, 16, 8));
        b.dw("dw1", 3, 2, Act::Relu).pw("pw1", 16, Act::None);
        assert_eq!(b.cursor(), (8, 8, 16));
        let net = b.build();
        assert_eq!(net.layers.len(), 3);
    }

    #[test]
    #[should_panic(expected = "expects cin")]
    fn channel_mismatch_panics() {
        let mut b = NetBuilder::new("t", 32, 3);
        b.push("bad".into(), OpKind::Pointwise { cin: 7, cout: 8 }, Act::None);
    }

    #[test]
    fn fuse_half_keeps_channels_full_doubles() {
        let mut b = NetBuilder::new("t", 16, 8);
        b.fuse("f", 3, 1, false, Act::Relu);
        assert_eq!(b.cursor(), (16, 16, 8));
        let mut b2 = NetBuilder::new("t2", 16, 8);
        b2.fuse("f", 3, 1, true, Act::Relu);
        assert_eq!(b2.cursor(), (16, 16, 16));
    }

    #[test]
    fn blocks_are_tracked() {
        let mut b = NetBuilder::new("t", 32, 8);
        let blk = b.begin_block();
        b.pw("expand", 48, Act::Relu6).dw("dw", 3, 1, Act::Relu6).pw("project", 8, Act::None);
        b.end_block();
        b.global_pool("pool");
        let net = b.build();
        assert_eq!(net.num_blocks, 1);
        assert_eq!(net.block_layers(blk).len(), 3);
        assert_eq!(net.bottleneck_blocks(), vec![0]);
        assert_eq!(net.layers.last().unwrap().block, None);
    }

    #[test]
    fn builder_threads_new_conv_variant_shapes() {
        let mut b = NetBuilder::new("t", 32, 8);
        b.dilated("aspp", 3, 1, 2, 16, Act::Relu);
        assert_eq!(b.cursor(), (32, 32, 16)); // stride 1, dilation ≠ subsample
        b.gconv("g", 3, 2, 4, 32, Act::Relu);
        assert_eq!(b.cursor(), (16, 16, 32));
        b.tconv("up", 4, 2, 16, Act::Relu);
        assert_eq!(b.cursor(), (32, 32, 16)); // upsampled back
        let net = b.build();
        assert_eq!(net.layers.len(), 3);
        assert!(net.total_macs() > 0 && net.total_params() > 0);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn gconv_rejects_non_dividing_groups() {
        let mut b = NetBuilder::new("t", 32, 8);
        b.gconv("bad", 3, 1, 3, 16, Act::None);
    }

    #[test]
    fn macs_by_class_splits() {
        let mut b = NetBuilder::new("t", 32, 8);
        b.begin_block();
        b.dw("dw", 3, 1, Act::Relu).pw("pw", 16, Act::None);
        b.end_block();
        let net = b.build();
        let by = net.macs_by_class();
        assert_eq!(by[&OpClass::Depthwise], 32 * 32 * 9 * 8);
        assert_eq!(by[&OpClass::Pointwise], 32 * 32 * 8 * 16);
        assert_eq!(net.total_macs(), by.values().sum::<u64>());
    }
}
