//! MnasNet-B1 (Tan et al., 2019), 224×224, width 1.0.
//! Paper Table 3 reference: 73.5 % top-1, 325 M MACs, 4.38 M params.

use super::mbconv;
use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

/// MnasNet-B1 stages: (kernel, expansion, channels, repeats, first-stride).
/// From the MnasNet paper Fig 7(a); B1 has no squeeze-excite.
const CFG: &[(usize, usize, usize, usize, usize)] = &[
    (3, 3, 24, 3, 2),
    (5, 3, 40, 3, 2),
    (5, 6, 80, 3, 2),
    (3, 6, 96, 2, 1),
    (5, 6, 192, 4, 2),
    (3, 6, 320, 1, 1),
];

pub fn build() -> Network {
    let mut b = NetBuilder::new("MnasNet-B1", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu);
    // SepConv block: dw3x3 + pw -> 16 (expansion 1)
    b.begin_block();
    b.dw("sep.dw", 3, 1, Act::Relu);
    b.pw("sep.pw", 16, Act::None);
    b.end_block();
    let mut idx = 0;
    for &(k, t, c, n, s) in CFG {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            let stride = if rep == 0 { s } else { 1 };
            mbconv(&mut b, &format!("b{idx}"), k, stride, cin * t, c, 0, Act::Relu);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fuse::{fuse_all, Variant};

    #[test]
    fn macs_and_params_match_table3() {
        let net = build();
        assert!((305.0..=340.0).contains(&net.macs_millions()), "{}", net.macs_millions());
        assert!((4.1..=4.6).contains(&net.params_millions()), "{}", net.params_millions());
    }

    #[test]
    fn seventeen_bottlenecks() {
        // sepconv + 16 MBConv blocks
        assert_eq!(build().bottleneck_blocks().len(), 17);
    }

    #[test]
    fn fuse_half_matches_table3() {
        // Table 3: 305 M MACs, 4.25 M params.
        let half = fuse_all(&build(), Variant::Half);
        assert!((290.0..=325.0).contains(&half.macs_millions()), "{}", half.macs_millions());
        assert!((4.0..=4.5).contains(&half.params_millions()));
    }

    #[test]
    fn fuse_full_matches_table3() {
        // Table 3: 440 M MACs, 5.66 M params.
        let full = fuse_all(&build(), Variant::Full);
        assert!((410.0..=470.0).contains(&full.macs_millions()), "{}", full.macs_millions());
        assert!((5.3..=6.0).contains(&full.params_millions()), "{}", full.params_millions());
    }

    #[test]
    fn kernel_five_stages_present() {
        use crate::nn::ops::OpKind;
        let ks: Vec<usize> = build()
            .layers
            .iter()
            .filter_map(|l| match l.op {
                OpKind::Depthwise { k, .. } => Some(k),
                _ => None,
            })
            .collect();
        assert_eq!(ks.iter().filter(|&&k| k == 5).count(), 10);
    }
}
