//! MobileNet-V2 (Sandler et al., 2018), 224×224, width 1.0.
//! Paper Table 3 reference: 72.0 % top-1, 315 M MACs, 3.50 M params.

use super::mbconv;
use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

/// Inverted-residual settings from the MobileNetV2 paper Table 2:
/// (expansion t, channels c, repeats n, first-stride s).
const CFG: &[(usize, usize, usize, usize)] = &[
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

pub fn build() -> Network {
    let mut b = NetBuilder::new("MobileNet-V2", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu6);
    let mut idx = 0;
    for &(t, c, n, s) in CFG {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            let stride = if rep == 0 { s } else { 1 };
            mbconv(&mut b, &format!("b{idx}"), 3, stride, cin * t, c, 0, Act::Relu6);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu6);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fuse::{fuse_all, Variant};
    use crate::nn::ops::OpClass;

    #[test]
    fn macs_and_params_match_table3() {
        let net = build();
        assert!((295.0..=330.0).contains(&net.macs_millions()), "{}", net.macs_millions());
        assert!((3.3..=3.7).contains(&net.params_millions()), "{}", net.params_millions());
    }

    #[test]
    fn seventeen_bottlenecks() {
        assert_eq!(build().bottleneck_blocks().len(), 17);
    }

    #[test]
    fn fuse_half_matches_table3() {
        // Table 3: 300 M MACs, 3.46 M params.
        let half = fuse_all(&build(), Variant::Half);
        assert!((285.0..=315.0).contains(&half.macs_millions()), "{}", half.macs_millions());
        assert!((3.25..=3.65).contains(&half.params_millions()));
    }

    #[test]
    fn fuse_full_matches_table3() {
        // Table 3: 430 M MACs, 4.46 M params.
        let full = fuse_all(&build(), Variant::Full);
        assert!((400.0..=460.0).contains(&full.macs_millions()), "{}", full.macs_millions());
        assert!((4.2..=4.8).contains(&full.params_millions()), "{}", full.params_millions());
    }

    #[test]
    fn depthwise_macs_are_small_fraction() {
        // The §2 motivation: dw is ~10 % of MACs yet dominates latency.
        let net = build();
        let by = net.macs_by_class();
        let dw = by[&OpClass::Depthwise] as f64;
        let total = net.total_macs() as f64;
        assert!(dw / total < 0.15, "dw fraction {}", dw / total);
        assert!(dw / total > 0.02);
    }

    #[test]
    fn spatial_pipeline_dims() {
        let net = build();
        // the last bottleneck runs at 7x7
        let last_dw = net
            .layers
            .iter()
            .filter(|l| matches!(l.class(), OpClass::Depthwise))
            .next_back()
            .unwrap();
        assert_eq!((last_dw.h, last_dw.w), (7, 7));
    }
}
