//! Segmentation-style zoo entries exercising the dilated / transposed /
//! grouped operators (ROADMAP item 4, EcoFlow/DRACO scenario space).
//!
//! These are *workload shapes*, not weight-exact reproductions: a
//! DeepLabV3-style dilated (ASPP) head on a MobileNetV2-ish backbone, and
//! an ESPNet-style encoder/decoder built from grouped reductions, dilated
//! context convs, and transposed-conv upsampling. Both keep at least one
//! depthwise bottleneck block so the paper's FuSe search space (which
//! rewrites dw blocks) applies to them unchanged.

use super::mbconv;
use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

/// DeepLabV3-style head: MBv2-ish backbone to stride 16, then a chain of
/// rate-2/4/6 dilated 3×3 convs standing in for the ASPP pyramid (the IR
/// is linear, so the parallel branches become a sequence with the same
/// per-branch shapes), projected down to 21 classes.
pub fn deeplab_mbv2() -> Network {
    let mut b = NetBuilder::new("DeepLab-MBv2", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu6); // 112
    mbconv(&mut b, "b0", 3, 1, 32, 16, 0, Act::Relu6);
    mbconv(&mut b, "b1", 3, 2, 96, 24, 0, Act::Relu6); // 56
    mbconv(&mut b, "b2", 3, 1, 144, 24, 0, Act::Relu6);
    mbconv(&mut b, "b3", 3, 2, 144, 32, 0, Act::Relu6); // 28
    mbconv(&mut b, "b4", 3, 1, 192, 32, 0, Act::Relu6);
    mbconv(&mut b, "b5", 3, 2, 192, 64, 0, Act::Relu6); // 14 (output stride 16)
    mbconv(&mut b, "b6", 3, 1, 384, 64, 0, Act::Relu6);
    // ASPP pyramid: same-resolution context at growing rates.
    b.dilated("aspp.r2", 3, 1, 2, 128, Act::Relu);
    b.dilated("aspp.r4", 3, 1, 4, 128, Act::Relu);
    b.dilated("aspp.r6", 3, 1, 6, 128, Act::Relu);
    b.pw("aspp.project", 256, Act::Relu);
    b.pw("classifier", 21, Act::None);
    b.build()
}

/// ESPNet-style encoder/decoder: grouped convs do the channel reduction
/// (the "point-wise group" trick), dilated convs the spatial pyramid, and
/// transposed convs the ×4 decoder upsampling — the exact operator trio
/// EcoFlow shows breaking the os/ws systolic dataflows.
pub fn espnet_c() -> Network {
    let mut b = NetBuilder::new("ESPNet-C", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu); // 112
    // one dw bottleneck so the FuSe search space has a handle here too
    mbconv(&mut b, "b0", 3, 1, 64, 32, 0, Act::Relu);
    b.gconv("enc1.down", 3, 2, 4, 64, Act::Relu); // 56
    b.gconv("enc1.reduce", 1, 1, 4, 32, Act::Relu);
    b.dilated("enc1.d2", 3, 1, 2, 64, Act::Relu);
    b.dilated("enc1.d4", 3, 1, 4, 64, Act::Relu);
    b.add("enc1.add");
    b.gconv("enc2.down", 3, 2, 8, 128, Act::Relu); // 28
    b.gconv("enc2.reduce", 1, 1, 8, 64, Act::Relu);
    b.dilated("enc2.d2", 3, 1, 2, 128, Act::Relu);
    b.dilated("enc2.d8", 3, 1, 8, 128, Act::Relu);
    b.add("enc2.add");
    b.tconv("dec1.up", 4, 2, 64, Act::Relu); // 56
    b.gconv("dec1.refine", 3, 1, 4, 64, Act::Relu);
    b.tconv("dec2.up", 4, 2, 32, Act::Relu); // 112
    b.pw("classifier", 20, Act::None);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops::OpKind;

    #[test]
    fn deeplab_builds_with_dilated_head() {
        let net = deeplab_mbv2();
        assert!(net.total_macs() > 0 && net.total_params() > 0);
        let dilated = net
            .layers
            .iter()
            .filter(|l| matches!(l.op, OpKind::Dilated { .. }))
            .count();
        assert_eq!(dilated, 3);
        assert!(!net.bottleneck_blocks().is_empty());
        // the ASPP chain runs at the stride-16 resolution, undownsampled
        let aspp = net.layers.iter().find(|l| l.name == "aspp.r6").unwrap();
        assert_eq!((aspp.h, aspp.w), (14, 14));
        assert_eq!((aspp.out_h(), aspp.out_w()), (14, 14));
    }

    #[test]
    fn espnet_contains_all_three_new_operators() {
        let net = espnet_c();
        let has = |pred: fn(&OpKind) -> bool| net.layers.iter().any(|l| pred(&l.op));
        assert!(has(|op| matches!(op, OpKind::Dilated { .. })));
        assert!(has(|op| matches!(op, OpKind::Transposed { .. })));
        assert!(has(|op| matches!(op, OpKind::Grouped { .. })));
        assert!(!net.bottleneck_blocks().is_empty());
        assert!(net.total_macs() > 0);
    }

    #[test]
    fn espnet_decoder_restores_half_resolution() {
        let net = espnet_c();
        let last = net.layers.last().unwrap();
        // classifier runs at 112×112: two ×2 transposed stages undo the
        // two grouped downsamples
        assert_eq!((last.h, last.w), (112, 112));
    }
}
