//! MobileNet-V3 Small & Large (Howard et al., 2019), 224×224, width 1.0.
//! Paper Table 3 reference: Small 67.4 % / 66 M MACs / 2.93 M params,
//! Large 75.2 % / 238 M MACs / 5.47 M params.

use super::mbconv;
use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

use Act::{HSwish as HS, Relu as RE};

/// Row of the MobileNetV3 spec tables: (k, exp, out, se, act, stride).
struct Row(usize, usize, usize, bool, Act, usize);

fn build_from(name: &str, rows: &[Row], last_conv: usize, head: usize) -> Network {
    let mut b = NetBuilder::new(name, 224, 3);
    b.conv("stem", 3, 2, 16, HS);
    for (i, &Row(k, exp, out, se, act, s)) in rows.iter().enumerate() {
        // V3 SE reduces the *expanded* channels by 4 (nearest multiple of 8).
        let se_reduced = if se { ((exp / 4) + 7) / 8 * 8 } else { 0 };
        mbconv(&mut b, &format!("b{i}"), k, s, exp, out, se_reduced, act);
    }
    b.conv("last_conv", 1, 1, last_conv, HS);
    b.global_pool("pool");
    b.fc("head", head, HS);
    b.fc("fc", 1000, Act::None);
    b.build()
}

pub fn large() -> Network {
    let rows = [
        Row(3, 16, 16, false, RE, 1),
        Row(3, 64, 24, false, RE, 2),
        Row(3, 72, 24, false, RE, 1),
        Row(5, 72, 40, true, RE, 2),
        Row(5, 120, 40, true, RE, 1),
        Row(5, 120, 40, true, RE, 1),
        Row(3, 240, 80, false, HS, 2),
        Row(3, 200, 80, false, HS, 1),
        Row(3, 184, 80, false, HS, 1),
        Row(3, 184, 80, false, HS, 1),
        Row(3, 480, 112, true, HS, 1),
        Row(3, 672, 112, true, HS, 1),
        Row(5, 672, 160, true, HS, 2),
        Row(5, 960, 160, true, HS, 1),
        Row(5, 960, 160, true, HS, 1),
    ];
    build_from("MobileNet-V3-Large", &rows, 960, 1280)
}

pub fn small() -> Network {
    let rows = [
        Row(3, 16, 16, true, RE, 2),
        Row(3, 72, 24, false, RE, 2),
        Row(3, 88, 24, false, RE, 1),
        Row(5, 96, 40, true, HS, 2),
        Row(5, 240, 40, true, HS, 1),
        Row(5, 240, 40, true, HS, 1),
        Row(5, 120, 48, true, HS, 1),
        Row(5, 144, 48, true, HS, 1),
        Row(5, 288, 96, true, HS, 2),
        Row(5, 576, 96, true, HS, 1),
        Row(5, 576, 96, true, HS, 1),
    ];
    build_from("MobileNet-V3-Small", &rows, 576, 1024)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fuse::{fuse_all, Variant};

    #[test]
    fn large_matches_table3() {
        let net = large();
        assert!((215.0..=250.0).contains(&net.macs_millions()), "{}", net.macs_millions());
        assert!((5.0..=5.9).contains(&net.params_millions()), "{}", net.params_millions());
        assert_eq!(net.bottleneck_blocks().len(), 15);
    }

    #[test]
    fn small_matches_table3() {
        let net = small();
        assert!((55.0..=75.0).contains(&net.macs_millions()), "{}", net.macs_millions());
        assert!((2.4..=3.2).contains(&net.params_millions()), "{}", net.params_millions());
        assert_eq!(net.bottleneck_blocks().len(), 11);
    }

    #[test]
    fn large_fuse_half_matches_table3() {
        // Table 3: 225 M MACs, 5.40 M params.
        let half = fuse_all(&large(), Variant::Half);
        assert!((195.0..=240.0).contains(&half.macs_millions()), "{}", half.macs_millions());
        assert!((4.9..=5.8).contains(&half.params_millions()));
    }

    #[test]
    fn large_fuse_full_widens() {
        // Table 3: 322 M MACs (params 10.57 M includes their doubled-SE
        // accounting; we tolerate a range).
        let full = fuse_all(&large(), Variant::Full);
        assert!((290.0..=360.0).contains(&full.macs_millions()), "{}", full.macs_millions());
        assert!(full.params_millions() > large().params_millions());
    }

    #[test]
    fn small_has_se_in_first_block() {
        let net = small();
        assert!(net.layers.iter().any(|l| l.name == "b0.se"));
    }

    #[test]
    fn large_kernel_mix() {
        // V3-Large uses both 3x3 and 5x5 depthwise kernels.
        use crate::nn::ops::OpKind;
        let net = large();
        let ks: Vec<usize> = net
            .layers
            .iter()
            .filter_map(|l| match l.op {
                OpKind::Depthwise { k, .. } => Some(k),
                _ => None,
            })
            .collect();
        assert!(ks.contains(&3) && ks.contains(&5));
        assert_eq!(ks.len(), 15);
    }
}
