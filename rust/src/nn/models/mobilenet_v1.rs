//! MobileNet-V1 (Howard et al., 2017), 224×224, width 1.0.
//! Paper Table 3 reference: 70.60 % top-1, 589 M MACs, 4.23 M params.

use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

/// Depthwise-separable "block": dw 3×3 (stride s) + pw to `cout`.
fn sep(b: &mut NetBuilder, name: &str, stride: usize, cout: usize) {
    b.begin_block();
    b.dw(&format!("{name}.dw"), 3, stride, Act::Relu);
    b.pw(&format!("{name}.pw"), cout, Act::Relu);
    b.end_block();
}

pub fn build() -> Network {
    let mut b = NetBuilder::new("MobileNet-V1", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu);
    sep(&mut b, "sep1", 1, 64);
    sep(&mut b, "sep2", 2, 128);
    sep(&mut b, "sep3", 1, 128);
    sep(&mut b, "sep4", 2, 256);
    sep(&mut b, "sep5", 1, 256);
    sep(&mut b, "sep6", 2, 512);
    for i in 0..5 {
        sep(&mut b, &format!("sep7_{i}"), 1, 512);
    }
    sep(&mut b, "sep12", 2, 1024);
    sep(&mut b, "sep13", 1, 1024);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::fuse::{fuse_all, Variant};

    #[test]
    fn macs_and_params_match_table3() {
        let net = build();
        let macs_m = net.macs_millions();
        let params_m = net.params_millions();
        // Paper: 589 M MACs (the canonical 569 M figure counts slightly
        // differently), 4.23 M params. Allow 5 %.
        assert!((560.0..=620.0).contains(&macs_m), "MACs {macs_m}");
        assert!((4.0..=4.5).contains(&params_m), "params {params_m}");
    }

    #[test]
    fn thirteen_bottlenecks() {
        assert_eq!(build().bottleneck_blocks().len(), 13);
    }

    #[test]
    fn fuse_half_close_to_table3() {
        // Table 3: MobileNet-V1 FuSe-Half = 573 M MACs, 4.20 M params.
        let half = fuse_all(&build(), Variant::Half);
        assert!((540.0..=600.0).contains(&half.macs_millions()), "{}", half.macs_millions());
        assert!((3.9..=4.45).contains(&half.params_millions()));
    }

    #[test]
    fn fuse_full_close_to_table3() {
        // Table 3: FuSe-Full = 1122 M MACs, 7.36 M params (pointwise inputs
        // double).
        let full = fuse_all(&build(), Variant::Full);
        assert!((1000.0..=1200.0).contains(&full.macs_millions()), "{}", full.macs_millions());
        assert!((6.8..=7.9).contains(&full.params_millions()), "{}", full.params_millions());
    }

    #[test]
    fn final_feature_map_is_7x7() {
        let net = build();
        // layer before pool
        let pre_pool = &net.layers[net.layers.len() - 3];
        assert_eq!((pre_pool.out_h(), pre_pool.out_w()), (7, 7));
        assert_eq!(pre_pool.out_c(), 1024);
    }
}
