//! Table-4 comparison networks.
//!
//! These are documented *reconstructions* (DESIGN.md §Substitutions): the
//! exact per-layer configurations of ProxylessNAS / Single-Path NAS /
//! FBNet-C / EfficientNet variants are taken from their papers where
//! published and approximated otherwise; each reconstruction's MAC/param
//! totals are asserted against the figures the FuSeConv paper quotes in
//! Table 4, which is what the latency comparison actually depends on.

use super::{fused_mbconv, mbconv};
use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;

/// ProxylessNAS (mobile, GPU-agnostic variant). Table 4: 320 M MACs, 4.08 M.
pub fn proxylessnas_mobile() -> Network {
    let mut b = NetBuilder::new("ProxylessNAS", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu6);
    // (k, t, c, n, s) reconstruction of the proxyless-mobile genotype
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (5, 3, 32, 2, 2),
        (7, 3, 40, 4, 2),
        (7, 3, 80, 4, 2),
        (5, 3, 96, 4, 1),
        (7, 6, 192, 3, 2),
        (7, 6, 320, 1, 1),
    ];
    let mut idx = 0;
    for &(k, t, c, n, s) in cfg {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            mbconv(&mut b, &format!("b{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, 0, Act::Relu6);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu6);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

/// Single-Path NAS. Table 4: 332 M MACs, 4.42 M params.
pub fn single_path_nas() -> Network {
    let mut b = NetBuilder::new("Single-Path-NAS", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu6);
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (3, 3, 24, 2, 2),
        (5, 3, 40, 4, 2),
        (5, 6, 80, 4, 2),
        (5, 3, 96, 4, 1),
        (5, 6, 192, 4, 2),
        (3, 6, 320, 1, 1),
    ];
    let mut idx = 0;
    for &(k, t, c, n, s) in cfg {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            mbconv(&mut b, &format!("b{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, 0, Act::Relu6);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu6);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

/// FBNet-C. Table 4: 382 M MACs, 5.5 M params.
pub fn fbnet_c() -> Network {
    let mut b = NetBuilder::new("FBNet-C", 224, 3);
    b.conv("stem", 3, 2, 16, Act::Relu);
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (3, 6, 24, 3, 2),
        (5, 3, 32, 4, 2),
        (5, 6, 64, 3, 2),
        (5, 3, 112, 4, 1),
        (5, 6, 184, 3, 2),
        (3, 6, 352, 1, 1),
    ];
    let mut idx = 0;
    for &(k, t, c, n, s) in cfg {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            mbconv(&mut b, &format!("b{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, 0, Act::Relu);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1984, Act::Relu);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

/// EfficientNet-Lite0 (EfficientNet-B0 with SE removed, ReLU6, fixed head).
/// Table 4: 407 M MACs, 4.7 M params.
pub fn efficientnet_lite0() -> Network {
    let mut b = NetBuilder::new("EfficientNet-Lite0", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu6);
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (3, 6, 24, 2, 2),
        (5, 6, 40, 2, 2),
        (3, 6, 80, 3, 2),
        (5, 6, 112, 3, 1),
        (5, 6, 192, 4, 2),
        (3, 6, 320, 1, 1),
    ];
    let mut idx = 0;
    for &(k, t, c, n, s) in cfg {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            mbconv(&mut b, &format!("b{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, 0, Act::Relu6);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu6);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

/// EfficientNet-EdgeTPU-S: fused-MBConv early stages (full 3×3 convs in
/// place of expand+depthwise — the alternative utilization fix the paper
/// contrasts against). Table 4: 2351 M MACs, 5.43 M params.
pub fn efficientnet_edgetpu_s() -> Network {
    let mut b = NetBuilder::new("EfficientNet-EdgeTPU-S", 224, 3);
    b.conv("stem", 3, 2, 32, Act::Relu);
    // Fused stages (k, t, c, n, s)
    let fused: &[(usize, usize, usize, usize, usize)] = &[
        (3, 4, 24, 1, 1),
        (3, 8, 32, 3, 2),
        (3, 8, 48, 4, 2),
    ];
    let mut idx = 0;
    for &(k, t, c, n, s) in fused {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            fused_mbconv(&mut b, &format!("f{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, Act::Relu);
            idx += 1;
        }
    }
    // Regular MBConv tail
    let tail: &[(usize, usize, usize, usize, usize)] = &[
        (3, 8, 96, 5, 2),
        (3, 8, 144, 4, 1),
        (5, 8, 192, 2, 2),
    ];
    for &(k, t, c, n, s) in tail {
        for rep in 0..n {
            let (_, _, cin) = b.cursor();
            mbconv(&mut b, &format!("b{idx}"), k, if rep == 0 { s } else { 1 }, cin * t, c, 0, Act::Relu);
            idx += 1;
        }
    }
    b.conv("head", 1, 1, 1280, Act::Relu);
    b.global_pool("pool");
    b.fc("fc", 1000, Act::None);
    b.build()
}

/// Once-For-All best-reported subnet. Table 4: 369 M MACs, 6.55 M params.
pub fn ofa_baseline() -> Network {
    super::ofa::OfaGenome::reference_ofa().realize("OFA")
}

/// FuSe-OFA-1 (ours, Table 4: 376 M MACs, 6.85 M params, 76.7 %).
pub fn fuse_ofa_1() -> Network {
    super::ofa::OfaGenome::reference_fuse_ofa_1().realize("FuSe-OFA-1")
}

/// FuSe-OFA-2 (ours, Table 4: 426 M MACs, 7.29 M params, 77.2 %).
pub fn fuse_ofa_2() -> Network {
    super::ofa::OfaGenome::reference_fuse_ofa_2().realize("FuSe-OFA-2")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_near(net: &Network, macs_m: f64, params_m: f64, tol: f64) {
        let m = net.macs_millions();
        let p = net.params_millions();
        assert!(
            (m - macs_m).abs() / macs_m < tol,
            "{}: MACs {m:.1}M vs paper {macs_m}M",
            net.name
        );
        assert!(
            (p - params_m).abs() / params_m < tol + 0.05,
            "{}: params {p:.2}M vs paper {params_m}M",
            net.name
        );
    }

    #[test]
    fn proxylessnas_near_table4() {
        assert_near(&proxylessnas_mobile(), 320.0, 4.08, 0.12);
    }

    #[test]
    fn single_path_nas_near_table4() {
        assert_near(&single_path_nas(), 332.0, 4.42, 0.12);
    }

    #[test]
    fn fbnet_c_near_table4() {
        assert_near(&fbnet_c(), 382.0, 5.5, 0.12);
    }

    #[test]
    fn efficientnet_lite0_near_table4() {
        assert_near(&efficientnet_lite0(), 407.0, 4.7, 0.12);
    }

    #[test]
    fn edgetpu_s_is_mac_heavy() {
        let net = efficientnet_edgetpu_s();
        // Table 4: 2351 M — > 5x every depthwise model. The fused blocks
        // must dominate.
        assert!(net.macs_millions() > 1800.0, "{}", net.macs_millions());
        assert!(net.params_millions() < 8.0);
    }

    #[test]
    fn fuse_ofa_nets_contain_fuse_ops() {
        use crate::nn::ops::OpClass;
        for net in [fuse_ofa_1(), fuse_ofa_2()] {
            let by = net.macs_by_class();
            assert!(by.contains_key(&OpClass::FuSe), "{} has no FuSe ops", net.name);
        }
    }

    #[test]
    fn ofa_nets_near_table4() {
        assert_near(&ofa_baseline(), 369.0, 6.55, 0.2);
        assert_near(&fuse_ofa_1(), 376.0, 6.85, 0.2);
        assert_near(&fuse_ofa_2(), 426.0, 7.29, 0.2);
    }
}
