//! Model zoo: the paper's evaluation networks reconstructed layer-by-layer
//! from their source papers (MobileNet V1/V2/V3, MnasNet-B1), plus the
//! Table-4 NAS comparison points and the OFA search space.
//!
//! MAC/parameter totals are asserted against the paper's Table 3/Table 4
//! values in each module's tests (within a small tolerance — the paper
//! rounds to millions and differs slightly in counting conventions for
//! bias/BN terms).

pub mod mnasnet;
pub mod mobilenet_v1;
pub mod mobilenet_v2;
pub mod mobilenet_v3;
pub mod nas_zoo;
pub mod ofa;
pub mod segmentation;

use super::graph::{NetBuilder, Network};
use super::ops::Act;

/// Inverted-residual (MBConv) block shared by every network in the zoo:
/// optional expand 1×1 → depthwise k×k (stride s) → optional SE → project
/// 1×1 → optional residual add. `se_reduced == 0` disables SE.
pub fn mbconv(
    b: &mut NetBuilder,
    name: &str,
    k: usize,
    stride: usize,
    expand: usize,
    cout: usize,
    se_reduced: usize,
    act: Act,
) {
    let (_, _, cin) = b.cursor();
    let residual = stride == 1 && cin == cout;
    b.begin_block();
    if expand != cin {
        b.pw(&format!("{name}.expand"), expand, act);
    }
    b.dw(&format!("{name}.dw"), k, stride, act);
    if se_reduced > 0 {
        b.se(&format!("{name}.se"), se_reduced);
    }
    b.pw(&format!("{name}.project"), cout, Act::None);
    if residual {
        b.add(&format!("{name}.add"));
    }
    b.end_block();
}

/// Fused-MBConv (EfficientNet-EdgeTPU): the expand 1×1 + depthwise k×k are
/// replaced by a single full k×k convolution — the paper's §7 notes this
/// costs up to 12× the MACs but utilizes systolic hardware.
pub fn fused_mbconv(
    b: &mut NetBuilder,
    name: &str,
    k: usize,
    stride: usize,
    expand: usize,
    cout: usize,
    act: Act,
) {
    let (_, _, cin) = b.cursor();
    let residual = stride == 1 && cin == cout;
    b.begin_block();
    b.conv(&format!("{name}.fused"), k, stride, expand, act);
    b.pw(&format!("{name}.project"), cout, Act::None);
    if residual {
        b.add(&format!("{name}.add"));
    }
    b.end_block();
}

/// Look a zoo network up by CLI name.
pub fn by_name(name: &str) -> Option<Network> {
    Some(match name {
        "mobilenet-v1" | "mbv1" => mobilenet_v1::build(),
        "mobilenet-v2" | "mbv2" => mobilenet_v2::build(),
        "mobilenet-v3-small" | "mbv3s" => mobilenet_v3::small(),
        "mobilenet-v3-large" | "mbv3l" => mobilenet_v3::large(),
        "mnasnet-b1" | "mnasnet" => mnasnet::build(),
        "proxylessnas" => nas_zoo::proxylessnas_mobile(),
        "single-path-nas" => nas_zoo::single_path_nas(),
        "fbnet-c" => nas_zoo::fbnet_c(),
        "efficientnet-lite0" => nas_zoo::efficientnet_lite0(),
        "efficientnet-edgetpu-s" => nas_zoo::efficientnet_edgetpu_s(),
        "ofa" => nas_zoo::ofa_baseline(),
        "fuse-ofa-1" => nas_zoo::fuse_ofa_1(),
        "fuse-ofa-2" => nas_zoo::fuse_ofa_2(),
        "deeplab-mbv2" | "deeplab" => segmentation::deeplab_mbv2(),
        "espnet-c" | "espnet" => segmentation::espnet_c(),
        _ => return None,
    })
}

/// Zoo keys of the five efficient networks of Fig 8(a)/Table 3 — the
/// one list behind both [`paper_five`] and the CLI's `--models paper5`
/// (local and `--remote` sweep paths address models by these names).
pub const PAPER_FIVE_NAMES: &[&str] = &[
    "mobilenet-v1",
    "mobilenet-v2",
    "mobilenet-v3-small",
    "mobilenet-v3-large",
    "mnasnet-b1",
];

/// The five efficient networks of Fig 8(a)/Table 3.
pub fn paper_five() -> Vec<Network> {
    PAPER_FIVE_NAMES
        .iter()
        .map(|n| by_name(n).expect("paper-five names resolve in the zoo"))
        .collect()
}

/// One row per zoo network: `(name, MACs in millions, params in
/// millions, #bottleneck blocks)`. Shared by `fuseconv zoo` and the
/// serving protocol's `Zoo` reply, so both surfaces list the same facts.
pub fn zoo_table() -> Vec<(&'static str, f64, f64, usize)> {
    ZOO_NAMES
        .iter()
        .map(|&name| {
            let net = by_name(name).expect("ZOO_NAMES entries resolve");
            (name, net.macs_millions(), net.params_millions(), net.bottleneck_blocks().len())
        })
        .collect()
}

pub const ZOO_NAMES: &[&str] = &[
    "mobilenet-v1",
    "mobilenet-v2",
    "mobilenet-v3-small",
    "mobilenet-v3-large",
    "mnasnet-b1",
    "proxylessnas",
    "single-path-nas",
    "fbnet-c",
    "efficientnet-lite0",
    "efficientnet-edgetpu-s",
    "ofa",
    "fuse-ofa-1",
    "fuse-ofa-2",
    "deeplab-mbv2",
    "espnet-c",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in ZOO_NAMES {
            let net = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!net.layers.is_empty(), "{name} empty");
            assert!(net.total_macs() > 0);
        }
        assert!(by_name("nonesuch").is_none());
    }

    #[test]
    fn paper_five_are_the_evaluation_networks() {
        let names: Vec<String> = paper_five().iter().map(|n| n.name.clone()).collect();
        assert_eq!(names.len(), 5);
        assert!(names.iter().any(|n| n.contains("V3-Large")));
    }

    #[test]
    fn zoo_table_covers_every_network() {
        let table = zoo_table();
        assert_eq!(table.len(), ZOO_NAMES.len());
        for (name, macs_m, params_m, blocks) in table {
            assert!(macs_m > 0.0, "{name} zero MACs");
            assert!(params_m > 0.0, "{name} zero params");
            assert!(blocks > 0, "{name} zero blocks");
        }
    }

    #[test]
    fn every_zoo_network_has_bottlenecks() {
        for name in ZOO_NAMES {
            let net = by_name(name).unwrap();
            assert!(
                !net.bottleneck_blocks().is_empty(),
                "{name} has no dw/FuSe bottleneck blocks"
            );
        }
    }
}
