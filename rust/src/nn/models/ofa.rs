//! Once-For-All (OFA) search space (Cai et al., 2019) extended with the
//! per-block FuSeConv choice (paper §4.2 / §6.5, Fig 15).
//!
//! The space follows OFA's MobileNetV3-Large backbone: 5 stages with
//! elastic depth {2,3,4}, elastic expand ratio {3,4,6} ("width"), elastic
//! kernel {3,5,7} — and, in our extension, an elastic operator bit per
//! block: depthwise (false) or FuSe-Half (true).

use crate::nn::graph::{NetBuilder, Network};
use crate::nn::ops::Act;
use crate::rng::Rng;

pub const STAGE_WIDTHS: [usize; 5] = [24, 40, 80, 112, 160];
pub const STAGE_STRIDES: [usize; 5] = [2, 2, 2, 1, 2];
/// SE placement per stage as in MobileNetV3-Large.
pub const STAGE_SE: [bool; 5] = [false, true, false, true, true];
pub const MAX_DEPTH: usize = 4;
pub const KERNEL_CHOICES: [usize; 3] = [3, 5, 7];
pub const EXPAND_CHOICES: [usize; 3] = [3, 4, 6];

/// One block's elastic settings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGene {
    pub kernel: usize,
    pub expand: usize,
    pub fuse: bool,
}

/// Full genome: per-stage depth + per-block genes (MAX_DEPTH slots per
/// stage; slots beyond `depth` are ignored but kept so mutation is uniform).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OfaGenome {
    pub depths: [usize; 5],
    pub blocks: [[BlockGene; MAX_DEPTH]; 5],
    /// Whether the search may use FuSe at all (Fig 15's baseline curve
    /// fixes this to false).
    pub allow_fuse: bool,
}

impl OfaGenome {
    pub fn uniform(kernel: usize, expand: usize, depth: usize, fuse: bool) -> OfaGenome {
        OfaGenome {
            depths: [depth; 5],
            blocks: [[BlockGene { kernel, expand, fuse }; MAX_DEPTH]; 5],
            allow_fuse: fuse,
        }
    }

    /// Random genome (NAS sampling).
    pub fn random(rng: &mut Rng, allow_fuse: bool) -> OfaGenome {
        let mut g = OfaGenome::uniform(3, 4, 3, false);
        g.allow_fuse = allow_fuse;
        for s in 0..5 {
            g.depths[s] = 2 + rng.below(3); // {2,3,4}
            for b in 0..MAX_DEPTH {
                g.blocks[s][b] = BlockGene {
                    kernel: *rng.choose(&KERNEL_CHOICES),
                    expand: *rng.choose(&EXPAND_CHOICES),
                    fuse: allow_fuse && rng.chance(0.5),
                };
            }
        }
        g
    }

    /// Mutate each gene with probability `p` (OFA/EA convention).
    pub fn mutate(&self, rng: &mut Rng, p: f64) -> OfaGenome {
        let mut g = self.clone();
        for s in 0..5 {
            if rng.chance(p) {
                g.depths[s] = 2 + rng.below(3);
            }
            for b in 0..MAX_DEPTH {
                if rng.chance(p) {
                    g.blocks[s][b].kernel = *rng.choose(&KERNEL_CHOICES);
                }
                if rng.chance(p) {
                    g.blocks[s][b].expand = *rng.choose(&EXPAND_CHOICES);
                }
                if g.allow_fuse && rng.chance(p) {
                    g.blocks[s][b].fuse = !g.blocks[s][b].fuse;
                }
            }
        }
        g
    }

    /// Uniform crossover.
    pub fn crossover(&self, other: &OfaGenome, rng: &mut Rng) -> OfaGenome {
        let mut g = self.clone();
        for s in 0..5 {
            if rng.chance(0.5) {
                g.depths[s] = other.depths[s];
            }
            for b in 0..MAX_DEPTH {
                if rng.chance(0.5) {
                    g.blocks[s][b] = other.blocks[s][b];
                }
            }
        }
        g.allow_fuse = self.allow_fuse || other.allow_fuse;
        g
    }

    /// Instantiate the genome as a concrete network.
    pub fn realize(&self, name: &str) -> Network {
        let mut b = NetBuilder::new(name, 224, 3);
        b.conv("stem", 3, 2, 16, Act::HSwish);
        // fixed first bottleneck (as in OFA's backbone)
        b.begin_block();
        b.dw("b0.dw", 3, 1, Act::Relu);
        b.pw("b0.project", 16, Act::None);
        b.end_block();
        let mut idx = 1;
        for s in 0..5 {
            for d in 0..self.depths[s] {
                let gene = self.blocks[s][d];
                let (_, _, cin) = b.cursor();
                let stride = if d == 0 { STAGE_STRIDES[s] } else { 1 };
                let cout = STAGE_WIDTHS[s];
                let expand = cin * gene.expand;
                let se_reduced = if STAGE_SE[s] { ((expand / 4) + 7) / 8 * 8 } else { 0 };
                let act = if s < 2 { Act::Relu } else { Act::HSwish };
                let residual = stride == 1 && cin == cout;
                let nm = format!("b{idx}");
                b.begin_block();
                b.pw(&format!("{nm}.expand"), expand, act);
                if gene.fuse {
                    b.fuse(&format!("{nm}.fuse"), gene.kernel, stride, false, act);
                } else {
                    b.dw(&format!("{nm}.dw"), gene.kernel, stride, act);
                }
                if se_reduced > 0 {
                    b.se(&format!("{nm}.se"), se_reduced);
                }
                b.pw(&format!("{nm}.project"), cout, Act::None);
                if residual {
                    b.add(&format!("{nm}.add"));
                }
                b.end_block();
                idx += 1;
            }
        }
        b.conv("last_conv", 1, 1, 960, Act::HSwish);
        b.global_pool("pool");
        b.fc("head", 1280, Act::HSwish);
        b.fc("fc", 1000, Act::None);
        b.build()
    }

    /// Total number of elastic blocks realized.
    pub fn num_blocks(&self) -> usize {
        self.depths.iter().sum::<usize>() + 1
    }

    /// Compact, deterministic string form for wire rows and log lines:
    /// one `d<depth>:<blocks>` group per stage, active blocks only,
    /// each block `k<kernel>e<expand>` plus `f` (FuSe) or `d`
    /// (depthwise). Equal genomes (over their active slots) produce
    /// equal strings, so streamed search rows compare bytewise.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        for stage in 0..5 {
            if stage > 0 {
                s.push('/');
            }
            s.push_str(&format!("d{}:", self.depths[stage]));
            for b in 0..self.depths[stage] {
                if b > 0 {
                    s.push('.');
                }
                let g = self.blocks[stage][b];
                s.push_str(&format!(
                    "k{}e{}{}",
                    g.kernel,
                    g.expand,
                    if g.fuse { 'f' } else { 'd' }
                ));
            }
        }
        s
    }

    // ---- Reference genomes for Table 4 (searched; frozen for
    // reproducibility — see EXPERIMENTS.md E15) ----

    /// Baseline OFA subnet matching the paper's quoted 369 M MACs.
    pub fn reference_ofa() -> OfaGenome {
        let mut g = OfaGenome::uniform(5, 6, 3, false);
        g.depths = [3, 3, 4, 4, 4];
        for s in 0..5 {
            for d in 0..MAX_DEPTH {
                g.blocks[s][d].kernel = if s >= 3 { 7 } else { 5 };
                g.blocks[s][d].expand = if s == 0 { 4 } else { 6 };
            }
        }
        g
    }

    /// FuSe-OFA-1: latency-leaning searched net (Table 4: 376 M, 76.7 %).
    pub fn reference_fuse_ofa_1() -> OfaGenome {
        let mut g = Self::reference_ofa();
        g.allow_fuse = true;
        for s in 0..5 {
            for d in 0..MAX_DEPTH {
                g.blocks[s][d].fuse = true;
                // FuSe rows/cols are cheap; search selected wider kernels
                g.blocks[s][d].kernel = 7;
                g.blocks[s][d].expand = 6;
            }
        }
        g.depths = [3, 3, 4, 4, 4];
        g
    }

    /// FuSe-OFA-2: accuracy-leaning searched net (Table 4: 426 M, 77.2 %).
    pub fn reference_fuse_ofa_2() -> OfaGenome {
        let mut g = Self::reference_fuse_ofa_1();
        g.depths = [4, 4, 4, 4, 4];
        // two hybrid depthwise blocks retained where the EA kept them —
        // late, low-resolution stages (high accuracy weight, little latency)
        g.blocks[3][0].fuse = false;
        g.blocks[4][0].fuse = false;
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realize_produces_valid_network() {
        let g = OfaGenome::uniform(3, 4, 3, false);
        let net = g.realize("t");
        assert_eq!(net.bottleneck_blocks().len(), g.num_blocks());
        assert!(net.total_macs() > 0);
    }

    #[test]
    fn random_genomes_in_bounds() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let g = OfaGenome::random(&mut rng, true);
            for s in 0..5 {
                assert!((2..=4).contains(&g.depths[s]));
                for b in 0..MAX_DEPTH {
                    assert!(KERNEL_CHOICES.contains(&g.blocks[s][b].kernel));
                    assert!(EXPAND_CHOICES.contains(&g.blocks[s][b].expand));
                }
            }
            // realizable
            let net = g.realize("r");
            assert!(net.total_macs() > 0);
        }
    }

    #[test]
    fn no_fuse_when_disallowed() {
        let mut rng = Rng::new(12);
        for _ in 0..20 {
            let g = OfaGenome::random(&mut rng, false);
            let net = g.realize("nf");
            use crate::nn::ops::OpClass;
            assert!(!net.macs_by_class().contains_key(&OpClass::FuSe));
        }
    }

    #[test]
    fn mutation_changes_something_eventually() {
        let mut rng = Rng::new(13);
        let g = OfaGenome::uniform(3, 4, 3, true);
        let mut changed = false;
        for _ in 0..20 {
            if g.mutate(&mut rng, 0.3) != g {
                changed = true;
                break;
            }
        }
        assert!(changed);
    }

    #[test]
    fn crossover_mixes_parents() {
        let mut rng = Rng::new(14);
        let a = OfaGenome::uniform(3, 3, 2, false);
        let b = OfaGenome::uniform(7, 6, 4, false);
        let c = a.crossover(&b, &mut rng);
        // depth genes must come from one of the parents
        for s in 0..5 {
            assert!(c.depths[s] == 2 || c.depths[s] == 4);
        }
    }

    #[test]
    fn reference_genomes_realize() {
        for (g, lo, hi) in [
            (OfaGenome::reference_ofa(), 280.0, 460.0),
            (OfaGenome::reference_fuse_ofa_1(), 280.0, 470.0),
            (OfaGenome::reference_fuse_ofa_2(), 320.0, 530.0),
        ] {
            let net = g.realize("ref");
            let m = net.macs_millions();
            assert!((lo..=hi).contains(&m), "MACs {m}");
        }
    }

    #[test]
    fn deeper_genome_has_more_macs() {
        let shallow = OfaGenome::uniform(3, 3, 2, false).realize("s");
        let deep = OfaGenome::uniform(3, 3, 4, false).realize("d");
        assert!(deep.total_macs() > shallow.total_macs());
    }
}
