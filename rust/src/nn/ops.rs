//! Operator kinds in the network IR.
//!
//! The IR mirrors what SCALE-Sim-FuSe consumes: each layer is one hardware-
//! mappable operator with explicit shapes. FuSeConv appears as the pair
//! `FuseRow` + `FuseCol` (paper §3.1): 1×K row filters and K×1 column
//! filters. The `Half` variant gives each half of the channels to one
//! orientation; `Full` runs both orientations over all channels.

/// Nonlinearity attached to a layer. Irrelevant to cycle counts (the paper's
/// simulator ignores activations too) but kept so the IR can regenerate the
/// exact network definitions and parameter counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Relu6,
    HSwish,
    HSigmoid,
}

/// One hardware-mappable operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Standard spatial convolution `k×k`, `cin → cout`.
    Conv2d { k: usize, stride: usize, cin: usize, cout: usize },
    /// Depthwise convolution `k×k` over `c` channels (one filter/channel).
    Depthwise { k: usize, stride: usize, c: usize },
    /// 1×1 convolution (pointwise), `cin → cout`.
    Pointwise { cin: usize, cout: usize },
    /// FuSeConv row half: `1×k` filters over `c` channels.
    FuseRow { k: usize, stride: usize, c: usize },
    /// FuSeConv column half: `k×1` filters over `c` channels.
    FuseCol { k: usize, stride: usize, c: usize },
    /// Fully connected `cin → cout` (batch-1 GEMV).
    Fc { cin: usize, cout: usize },
    /// Global average pool over `c` channels.
    GlobalPool { c: usize },
    /// Squeeze-and-excite block: pool + FC(c→r) + FC(r→c) + scale.
    SqueezeExcite { c: usize, reduced: usize },
    /// Residual elementwise add over `c` channels.
    Add { c: usize },
    /// Dilated spatial convolution: `k×k` taps spaced `dilation` apart
    /// (effective receptive field `k + (k-1)(dilation-1)`), `cin → cout`.
    /// MAC/param counts equal the dense conv; the inflated window is a
    /// pure scheduling problem (EcoFlow).
    Dilated { k: usize, stride: usize, dilation: usize, cin: usize, cout: usize },
    /// Transposed (fractionally-strided) convolution: upsamples `h×w` to
    /// `h·stride × w·stride`. Lowered via zero-insertion under the GEMM
    /// dataflows — the inefficiency EcoFlow targets.
    Transposed { k: usize, stride: usize, cin: usize, cout: usize },
    /// Grouped convolution: `groups` independent `k×k` convs over
    /// `cin/groups → cout/groups` channel slices each.
    Grouped { k: usize, stride: usize, groups: usize, cin: usize, cout: usize },
}

/// Coarse operator class used by the paper's Fig 9(a) latency attribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    Depthwise,
    Pointwise,
    FuSe,
    OtherConv,
    Other,
}

impl OpKind {
    pub fn class(&self) -> OpClass {
        match self {
            OpKind::Depthwise { .. } => OpClass::Depthwise,
            OpKind::Pointwise { .. } => OpClass::Pointwise,
            OpKind::FuseRow { .. } | OpKind::FuseCol { .. } => OpClass::FuSe,
            OpKind::Conv2d { .. }
            | OpKind::Dilated { .. }
            | OpKind::Transposed { .. }
            | OpKind::Grouped { .. } => OpClass::OtherConv,
            OpKind::Fc { .. }
            | OpKind::GlobalPool { .. }
            | OpKind::SqueezeExcite { .. }
            | OpKind::Add { .. } => OpClass::Other,
        }
    }

    /// Output channel count.
    pub fn cout(&self) -> usize {
        match *self {
            OpKind::Conv2d { cout, .. } => cout,
            OpKind::Depthwise { c, .. } => c,
            OpKind::Pointwise { cout, .. } => cout,
            OpKind::FuseRow { c, .. } => c,
            OpKind::FuseCol { c, .. } => c,
            OpKind::Fc { cout, .. } => cout,
            OpKind::GlobalPool { c } => c,
            OpKind::SqueezeExcite { c, .. } => c,
            OpKind::Add { c } => c,
            OpKind::Dilated { cout, .. }
            | OpKind::Transposed { cout, .. }
            | OpKind::Grouped { cout, .. } => cout,
        }
    }

    /// Input channel count.
    pub fn cin(&self) -> usize {
        match *self {
            OpKind::Conv2d { cin, .. } => cin,
            OpKind::Depthwise { c, .. } => c,
            OpKind::Pointwise { cin, .. } => cin,
            OpKind::FuseRow { c, .. } => c,
            OpKind::FuseCol { c, .. } => c,
            OpKind::Fc { cin, .. } => cin,
            OpKind::GlobalPool { c } => c,
            OpKind::SqueezeExcite { c, .. } => c,
            OpKind::Add { c } => c,
            OpKind::Dilated { cin, .. }
            | OpKind::Transposed { cin, .. }
            | OpKind::Grouped { cin, .. } => cin,
        }
    }

    pub fn stride(&self) -> usize {
        match *self {
            OpKind::Conv2d { stride, .. }
            | OpKind::Depthwise { stride, .. }
            | OpKind::FuseRow { stride, .. }
            | OpKind::FuseCol { stride, .. }
            | OpKind::Dilated { stride, .. }
            | OpKind::Transposed { stride, .. }
            | OpKind::Grouped { stride, .. } => stride,
            _ => 1,
        }
    }

    /// Trainable parameter count (weights only; BN folded, bias on FC).
    pub fn params(&self) -> u64 {
        match *self {
            OpKind::Conv2d { k, cin, cout, .. } => (k * k * cin * cout) as u64,
            OpKind::Depthwise { k, c, .. } => (k * k * c) as u64,
            OpKind::Pointwise { cin, cout } => (cin * cout) as u64,
            OpKind::FuseRow { k, c, .. } | OpKind::FuseCol { k, c, .. } => (k * c) as u64,
            OpKind::Fc { cin, cout } => (cin * cout + cout) as u64,
            OpKind::GlobalPool { .. } | OpKind::Add { .. } => 0,
            OpKind::SqueezeExcite { c, reduced } => (c * reduced + reduced + reduced * c + c) as u64,
            // Dilation spaces the taps out but adds none: dense-conv params.
            OpKind::Dilated { k, cin, cout, .. } => (k * k * cin * cout) as u64,
            OpKind::Transposed { k, cin, cout, .. } => (k * k * cin * cout) as u64,
            OpKind::Grouped { k, groups, cin, cout, .. } => {
                (k * k * (cin / groups.max(1)) * cout) as u64
            }
        }
    }

    /// Effective receptive-field edge of a dilated kernel:
    /// `k + (k-1)(dilation-1)` — the window the im2col gather must walk
    /// even though only `k` taps per axis are real weights.
    pub fn effective_k(k: usize, dilation: usize) -> usize {
        k + k.saturating_sub(1) * dilation.saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_as_in_fig9a() {
        assert_eq!(OpKind::Depthwise { k: 3, stride: 1, c: 8 }.class(), OpClass::Depthwise);
        assert_eq!(OpKind::Pointwise { cin: 8, cout: 16 }.class(), OpClass::Pointwise);
        assert_eq!(OpKind::FuseRow { k: 3, stride: 1, c: 4 }.class(), OpClass::FuSe);
        assert_eq!(OpKind::FuseCol { k: 3, stride: 1, c: 4 }.class(), OpClass::FuSe);
        assert_eq!(OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 }.class(), OpClass::OtherConv);
        assert_eq!(OpKind::Fc { cin: 1280, cout: 1000 }.class(), OpClass::Other);
    }

    #[test]
    fn param_counts() {
        // depthwise 3x3 over 32 ch = 288; FuSe row 3 over 16 ch = 48
        assert_eq!(OpKind::Depthwise { k: 3, stride: 1, c: 32 }.params(), 288);
        assert_eq!(OpKind::FuseRow { k: 3, stride: 1, c: 16 }.params(), 48);
        assert_eq!(OpKind::Pointwise { cin: 32, cout: 64 }.params(), 2048);
        assert_eq!(OpKind::Fc { cin: 10, cout: 5 }.params(), 55);
        assert_eq!(OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 }.params(), 864);
    }

    #[test]
    fn fuse_pair_param_reduction_matches_paper() {
        // Paper §3.2.1: depthwise K² C params -> FuSe-Half K C params
        // (row K·C/2 + col K·C/2).
        let c = 128;
        let k = 3;
        let dw = OpKind::Depthwise { k, stride: 1, c }.params();
        let half = OpKind::FuseRow { k, stride: 1, c: c / 2 }.params()
            + OpKind::FuseCol { k, stride: 1, c: c / 2 }.params();
        assert_eq!(dw, (k * k * c) as u64);
        assert_eq!(half, (k * c) as u64);
        assert_eq!(dw / half, k as u64);
    }

    #[test]
    fn new_conv_variants_params_match_analytical_formulas() {
        // dilated = dense conv params (taps spaced, not added)
        let d = OpKind::Dilated { k: 3, stride: 1, dilation: 2, cin: 32, cout: 64 };
        assert_eq!(d.params(), 3 * 3 * 32 * 64);
        // transposed = K²·Cin·Cout, same as forward conv
        let t = OpKind::Transposed { k: 4, stride: 2, cin: 64, cout: 32 };
        assert_eq!(t.params(), 4 * 4 * 64 * 32);
        // grouped = K²·(Cin/G)·Cout — a G× reduction vs dense
        let g = OpKind::Grouped { k: 3, stride: 1, groups: 4, cin: 32, cout: 64 };
        assert_eq!(g.params(), 3 * 3 * (32 / 4) * 64);
        let dense = OpKind::Conv2d { k: 3, stride: 1, cin: 32, cout: 64 };
        assert_eq!(dense.params(), g.params() * 4);
    }

    #[test]
    fn new_conv_variants_accessors_and_class() {
        let d = OpKind::Dilated { k: 3, stride: 2, dilation: 2, cin: 8, cout: 16 };
        assert_eq!((d.cin(), d.cout(), d.stride()), (8, 16, 2));
        assert_eq!(d.class(), OpClass::OtherConv);
        let t = OpKind::Transposed { k: 4, stride: 2, cin: 16, cout: 8 };
        assert_eq!((t.cin(), t.cout(), t.stride()), (16, 8, 2));
        assert_eq!(t.class(), OpClass::OtherConv);
        let g = OpKind::Grouped { k: 3, stride: 1, groups: 2, cin: 8, cout: 8 };
        assert_eq!((g.cin(), g.cout(), g.stride()), (8, 8, 1));
        assert_eq!(g.class(), OpClass::OtherConv);
    }

    #[test]
    fn effective_k_inflates_with_dilation() {
        assert_eq!(OpKind::effective_k(3, 1), 3);
        assert_eq!(OpKind::effective_k(3, 2), 5);
        assert_eq!(OpKind::effective_k(3, 4), 9);
        assert_eq!(OpKind::effective_k(1, 8), 1); // 1×1 can't dilate
    }

    #[test]
    fn cin_cout_stride_accessors() {
        let op = OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 };
        assert_eq!(op.cin(), 3);
        assert_eq!(op.cout(), 32);
        assert_eq!(op.stride(), 2);
        assert_eq!(OpKind::Fc { cin: 4, cout: 7 }.stride(), 1);
    }
}
