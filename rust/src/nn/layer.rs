//! A layer = one operator applied at a concrete spatial position in the
//! network, with exact output-shape / MAC accounting. These are the records
//! the simulator consumes and the quantities Tables 3–4 report.

use super::ops::{Act, OpClass, OpKind};

/// Concrete layer instance: operator + input spatial dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: String,
    pub op: OpKind,
    /// Input feature-map height/width (spatial), before padding.
    pub h: usize,
    pub w: usize,
    pub act: Act,
    /// Index of the mobile-bottleneck block this layer belongs to
    /// (None for stem/head layers). Used by Fig 8(b)/Fig 10 grouping.
    pub block: Option<usize>,
}

/// SAME-style padding as used by all the paper's networks: output spatial
/// size = ceil(input / stride).
fn out_dim(input: usize, stride: usize) -> usize {
    input.div_ceil(stride)
}

impl Layer {
    pub fn new(name: impl Into<String>, op: OpKind, h: usize, w: usize) -> Layer {
        Layer { name: name.into(), op, h, w, act: Act::None, block: None }
    }

    pub fn with_act(mut self, act: Act) -> Layer {
        self.act = act;
        self
    }

    pub fn in_block(mut self, b: usize) -> Layer {
        self.block = Some(b);
        self
    }

    /// Output spatial height.
    pub fn out_h(&self) -> usize {
        match self.op {
            OpKind::Fc { .. } | OpKind::GlobalPool { .. } => 1,
            OpKind::SqueezeExcite { .. } | OpKind::Add { .. } => self.h,
            // fractionally-strided: upsamples instead of subsampling
            OpKind::Transposed { stride, .. } => self.h * stride,
            op => out_dim(self.h, op.stride()),
        }
    }

    /// Output spatial width.
    pub fn out_w(&self) -> usize {
        match self.op {
            OpKind::Fc { .. } | OpKind::GlobalPool { .. } => 1,
            OpKind::SqueezeExcite { .. } | OpKind::Add { .. } => self.w,
            OpKind::Transposed { stride, .. } => self.w * stride,
            op => out_dim(self.w, op.stride()),
        }
    }

    pub fn out_c(&self) -> usize {
        self.op.cout()
    }

    /// Multiply-accumulate count (the unit Tables 3–4 use; one MAC = one
    /// multiply + one add).
    pub fn macs(&self) -> u64 {
        let (oh, ow) = (self.out_h() as u64, self.out_w() as u64);
        match self.op {
            OpKind::Conv2d { k, cin, cout, .. } => oh * ow * (k * k * cin * cout) as u64,
            OpKind::Depthwise { k, c, .. } => oh * ow * (k * k * c) as u64,
            OpKind::Pointwise { cin, cout } => oh * ow * (cin * cout) as u64,
            OpKind::FuseRow { k, c, .. } | OpKind::FuseCol { k, c, .. } => {
                oh * ow * (k * c) as u64
            }
            OpKind::Fc { cin, cout } => (cin * cout) as u64,
            // pool/add are not MACs; SE's two FCs are.
            OpKind::GlobalPool { .. } | OpKind::Add { .. } => 0,
            OpKind::SqueezeExcite { c, reduced } => 2 * (c * reduced) as u64,
            // dilation changes *where* taps land, never how many there are
            OpKind::Dilated { k, cin, cout, .. } => oh * ow * (k * k * cin * cout) as u64,
            // useful MACs of a transposed conv: every *input* pixel meets
            // the full kernel once — the zero-insertion waste is a
            // scheduling artifact, not arithmetic (see sim::engine).
            OpKind::Transposed { k, cin, cout, .. } => {
                (self.h * self.w) as u64 * (k * k * cin * cout) as u64
            }
            OpKind::Grouped { k, groups, cin, cout, .. } => {
                oh * ow * (k * k * (cin / groups.max(1)) * cout) as u64
            }
        }
    }

    pub fn params(&self) -> u64 {
        self.op.params()
    }

    pub fn class(&self) -> OpClass {
        self.op.class()
    }

    /// Input feature-map element count (for SRAM/DRAM footprint modelling).
    pub fn ifmap_elems(&self) -> u64 {
        (self.h * self.w) as u64 * self.op.cin() as u64
    }

    /// Output feature-map element count.
    pub fn ofmap_elems(&self) -> u64 {
        (self.out_h() * self.out_w()) as u64 * self.out_c() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_output_dims() {
        // stride-2 conv over 224 -> 112 (SAME)
        let l = Layer::new("stem", OpKind::Conv2d { k: 3, stride: 2, cin: 3, cout: 32 }, 224, 224);
        assert_eq!((l.out_h(), l.out_w(), l.out_c()), (112, 112, 32));
        // stride-2 over odd dim: 7 -> 4
        let l = Layer::new("x", OpKind::Depthwise { k: 3, stride: 2, c: 8 }, 7, 7);
        assert_eq!(l.out_h(), 4);
    }

    #[test]
    fn mac_formulas_match_paper_section2() {
        // Paper §2.1: standard conv NMC'K²C; depthwise-separable NMC(K²+C').
        let (h, w, c, cp, k) = (56usize, 56usize, 64usize, 128usize, 3usize);
        let std_conv = Layer::new("c", OpKind::Conv2d { k, stride: 1, cin: c, cout: cp }, h, w);
        assert_eq!(std_conv.macs(), (h * w * cp * k * k * c) as u64);

        let dw = Layer::new("d", OpKind::Depthwise { k, stride: 1, c }, h, w);
        let pw = Layer::new("p", OpKind::Pointwise { cin: c, cout: cp }, h, w);
        assert_eq!(dw.macs() + pw.macs(), (h * w * c * (k * k + cp)) as u64);
    }

    #[test]
    fn fuse_half_mac_reduction_matches_paper_3_2_1() {
        // Paper §3.2.1: NMC(K²+C') -> NMC(K+C').
        let (h, w, c, cp, k) = (28usize, 28usize, 96usize, 192usize, 3usize);
        let row = Layer::new("r", OpKind::FuseRow { k, stride: 1, c: c / 2 }, h, w);
        let col = Layer::new("c", OpKind::FuseCol { k, stride: 1, c: c / 2 }, h, w);
        let pw = Layer::new("p", OpKind::Pointwise { cin: c, cout: cp }, h, w);
        assert_eq!(row.macs() + col.macs() + pw.macs(), (h * w * c * (k + cp)) as u64);
    }

    #[test]
    fn dilated_macs_equal_dense_conv_twin() {
        // Same k/cin/cout/stride ⇒ identical MAC count at any dilation;
        // the difference is utilization, not arithmetic.
        let (h, w, k, cin, cout) = (33, 33, 3, 64, 128);
        let dense = Layer::new("c", OpKind::Conv2d { k, stride: 1, cin, cout }, h, w);
        for dilation in [1, 2, 4, 6] {
            let dil =
                Layer::new("d", OpKind::Dilated { k, stride: 1, dilation, cin, cout }, h, w);
            assert_eq!(dil.macs(), dense.macs());
            assert_eq!(dil.macs(), (h * w * k * k * cin * cout) as u64);
            assert_eq!((dil.out_h(), dil.out_w()), (h, w));
        }
    }

    #[test]
    fn transposed_upsamples_and_counts_input_side_macs() {
        let (h, w, k, s, cin, cout) = (16, 16, 4, 2, 64, 32);
        let t = Layer::new("up", OpKind::Transposed { k, stride: s, cin, cout }, h, w);
        assert_eq!((t.out_h(), t.out_w(), t.out_c()), (h * s, w * s, cout));
        // N·M·K²·C·C' over the *input* grid: each input pixel scatters
        // through the full kernel exactly once.
        assert_eq!(t.macs(), (h * w * k * k * cin * cout) as u64);
        assert_eq!(t.ofmap_elems(), (h * s * w * s * cout) as u64);
    }

    #[test]
    fn grouped_macs_divide_by_group_count() {
        let (h, w, k, cin, cout) = (28, 28, 3, 64, 64);
        let dense = Layer::new("c", OpKind::Conv2d { k, stride: 1, cin, cout }, h, w);
        for groups in [1, 2, 4, 8] {
            let g = Layer::new(
                "g",
                OpKind::Grouped { k, stride: 1, groups, cin, cout },
                h,
                w,
            );
            assert_eq!(g.macs(), dense.macs() / groups as u64);
        }
        // groups == cin degenerates to (a cout-replicated) depthwise cost
        let g = Layer::new(
            "g",
            OpKind::Grouped { k, stride: 1, groups: cin, cin, cout },
            h,
            w,
        );
        assert_eq!(g.macs(), (h * w * k * k * cout) as u64);
    }

    #[test]
    fn footprints() {
        let l = Layer::new("p", OpKind::Pointwise { cin: 16, cout: 32 }, 8, 8);
        assert_eq!(l.ifmap_elems(), 8 * 8 * 16);
        assert_eq!(l.ofmap_elems(), 8 * 8 * 32);
    }

    #[test]
    fn fc_and_pool_shapes() {
        let p = Layer::new("pool", OpKind::GlobalPool { c: 1280 }, 7, 7);
        assert_eq!((p.out_h(), p.out_w(), p.out_c()), (1, 1, 1280));
        assert_eq!(p.macs(), 0);
        let f = Layer::new("fc", OpKind::Fc { cin: 1280, cout: 1000 }, 1, 1);
        assert_eq!(f.macs(), 1_280_000);
        assert_eq!(f.params(), 1_281_000);
    }

    #[test]
    fn se_block_macs() {
        let se = Layer::new("se", OpKind::SqueezeExcite { c: 64, reduced: 16 }, 28, 28);
        assert_eq!(se.macs(), 2 * 64 * 16);
        assert_eq!(se.out_c(), 64);
        assert_eq!(se.out_h(), 28);
    }
}
