//! Network IR: operators, layers, graphs, the FuSeConv transform, and the
//! model zoo. This is the shared vocabulary between the simulator (S1), the
//! coordinator's search (S5/S6), and the report generators.

pub mod fuse;
pub mod graph;
pub mod layer;
pub mod models;
pub mod ops;

pub use fuse::{fuse_all, fuse_network, Selection, Variant};
pub use graph::{NetBuilder, Network};
pub use layer::Layer;
pub use ops::{Act, OpClass, OpKind};
