//! The FuSeConv in-place replacement transform (paper §3.1, §6.2).
//!
//! Given a baseline network with depthwise-separable bottlenecks, rewrite a
//! selected subset of its blocks so each depthwise K×K becomes the FuSe
//! row/column pair:
//!
//! * `Half` — row filters over C/2 channels, column filters over the other
//!   C/2; output stays C channels (a true drop-in).
//! * `Full` — both orientations over all C channels; output becomes 2C, so
//!   the *following* squeeze-excite and pointwise-project layers widen to 2C
//!   inputs (this is why Table 3's Full variants have more MACs/params than
//!   the baselines).

use super::graph::Network;
use super::layer::Layer;
use super::ops::OpKind;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Full,
    Half,
}

/// Which bottleneck blocks to convert.
#[derive(Debug, Clone)]
pub enum Selection {
    /// Every block containing a depthwise op.
    All,
    /// Exactly these block ids.
    Blocks(Vec<usize>),
    /// Bitmask over `net.bottleneck_blocks()` order (the EA genome).
    Mask(Vec<bool>),
}

impl Selection {
    fn selected_blocks(&self, net: &Network) -> Vec<usize> {
        let bn = net.bottleneck_blocks();
        match self {
            Selection::All => bn,
            Selection::Blocks(ids) => ids.clone(),
            Selection::Mask(mask) => {
                assert_eq!(
                    mask.len(),
                    bn.len(),
                    "mask length {} != bottleneck count {}",
                    mask.len(),
                    bn.len()
                );
                bn.into_iter().zip(mask).filter(|(_, &m)| m).map(|(b, _)| b).collect()
            }
        }
    }
}

/// Apply the FuSe transform. Returns a new network named
/// `{base}-FuSe-{Full|Half}[-partial]`.
pub fn fuse_network(net: &Network, variant: Variant, selection: &Selection) -> Network {
    let chosen: std::collections::BTreeSet<usize> =
        selection.selected_blocks(net).into_iter().collect();
    let total = net.bottleneck_blocks().len();
    let mut out: Vec<Layer> = Vec::with_capacity(net.layers.len() + chosen.len());

    // When a Full replacement doubles the live channel count we must widen
    // the next SE and the next pointwise in the same block.
    let mut widen_in_block: Option<usize> = None;

    for l in &net.layers {
        if widen_in_block.is_some() && l.block != widen_in_block {
            // Block ended without a pointwise? That would leave a dangling
            // 2C tensor — model definitions always project, so treat as bug.
            panic!("FuSe-Full: block ended before projecting 2C channels back");
        }
        match (l.op, l.block) {
            (OpKind::Depthwise { k, stride, c }, Some(b)) if chosen.contains(&b) => {
                let (rc, cc, outc) = match variant {
                    Variant::Full => (c, c, 2 * c),
                    Variant::Half => {
                        assert!(c % 2 == 0, "FuSe-Half on odd channel count {c}");
                        (c / 2, c / 2, c)
                    }
                };
                let mut row = Layer::new(
                    format!("{}.fuse_row", l.name),
                    OpKind::FuseRow { k, stride, c: rc },
                    l.h,
                    l.w,
                )
                .with_act(l.act);
                row.block = l.block;
                let mut col = Layer::new(
                    format!("{}.fuse_col", l.name),
                    OpKind::FuseCol { k, stride, c: cc },
                    l.h,
                    l.w,
                )
                .with_act(l.act);
                col.block = l.block;
                out.push(row);
                out.push(col);
                if outc == 2 * c {
                    widen_in_block = Some(b);
                }
            }
            (OpKind::SqueezeExcite { c, reduced }, _) if widen_in_block.is_some() => {
                let mut se = l.clone();
                se.op = OpKind::SqueezeExcite { c: 2 * c, reduced };
                out.push(se);
            }
            (OpKind::Pointwise { cin, cout }, _) if widen_in_block.is_some() => {
                let mut pw = l.clone();
                pw.op = OpKind::Pointwise { cin: 2 * cin, cout };
                out.push(pw);
                widen_in_block = None; // projection restores the width
            }
            _ => out.push(l.clone()),
        }
    }
    assert!(widen_in_block.is_none(), "FuSe-Full: unterminated widening");

    let suffix = match variant {
        Variant::Full => "FuSe-Full",
        Variant::Half => "FuSe-Half",
    };
    let partial = if chosen.len() < total {
        format!("-{}of{}", chosen.len(), total)
    } else {
        String::new()
    };
    Network {
        name: format!("{}-{}{}", net.name, suffix, partial),
        layers: out,
        num_blocks: net.num_blocks,
    }
}

/// Convenience: convert every depthwise block.
pub fn fuse_all(net: &Network, variant: Variant) -> Network {
    fuse_network(net, variant, &Selection::All)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::NetBuilder;
    use crate::nn::ops::Act;

    /// Two-block toy net shaped like MobileNetV2 bottlenecks.
    fn toy() -> Network {
        let mut b = NetBuilder::new("toy", 32, 3);
        b.conv("stem", 3, 2, 16, Act::Relu6);
        b.begin_block();
        b.pw("b0.expand", 48, Act::Relu6).dw("b0.dw", 3, 1, Act::Relu6).pw("b0.project", 24, Act::None);
        b.end_block();
        b.begin_block();
        b.pw("b1.expand", 144, Act::Relu6)
            .dw("b1.dw", 5, 2, Act::Relu6)
            .se("b1.se", 36)
            .pw("b1.project", 32, Act::None);
        b.end_block();
        b.global_pool("pool").fc("fc", 10, Act::None);
        b.build()
    }

    #[test]
    fn half_is_dropin_same_shapes() {
        let base = toy();
        let half = fuse_all(&base, Variant::Half);
        // one extra layer per converted dw (row+col replaces dw)
        assert_eq!(half.layers.len(), base.layers.len() + 2);
        // final cursor equivalence: last layers identical
        assert_eq!(half.layers.last().unwrap().op, base.layers.last().unwrap().op);
        // params strictly fewer (K²C -> KC per dw)
        assert!(half.total_params() < base.total_params());
        assert!(half.total_macs() < base.total_macs());
        assert!(half.name.contains("FuSe-Half"));
    }

    #[test]
    fn full_widens_se_and_project() {
        let base = toy();
        let full = fuse_all(&base, Variant::Full);
        // SE widened to 2C
        let se = full.layers.iter().find(|l| l.name == "b1.se").unwrap();
        assert_eq!(se.op, OpKind::SqueezeExcite { c: 288, reduced: 36 });
        // project widened input
        let pj = full.layers.iter().find(|l| l.name == "b1.project").unwrap();
        assert_eq!(pj.op, OpKind::Pointwise { cin: 288, cout: 32 });
        // Full has MORE macs+params than baseline (paper Table 3)
        assert!(full.total_macs() > base.total_macs());
        assert!(full.total_params() > base.total_params());
    }

    #[test]
    fn partial_selection_converts_subset() {
        let base = toy();
        let p = fuse_network(&base, Variant::Half, &Selection::Blocks(vec![1]));
        assert!(p.layers.iter().any(|l| l.name == "b0.dw")); // untouched
        assert!(p.layers.iter().any(|l| l.name == "b1.dw.fuse_row"));
        assert!(p.name.contains("1of2"));
    }

    #[test]
    fn mask_selection_matches_blocks() {
        let base = toy();
        let a = fuse_network(&base, Variant::Half, &Selection::Mask(vec![false, true]));
        let b = fuse_network(&base, Variant::Half, &Selection::Blocks(vec![1]));
        assert_eq!(a.total_macs(), b.total_macs());
        assert_eq!(a.layers.len(), b.layers.len());
    }

    #[test]
    fn half_macs_reduction_is_k_fold_on_dw() {
        use crate::nn::ops::OpClass;
        let base = toy();
        let half = fuse_all(&base, Variant::Half);
        let dw_macs = base.macs_by_class()[&OpClass::Depthwise];
        let fuse_macs = half.macs_by_class()[&OpClass::FuSe];
        // both blocks use k=3 and k=5: fuse = sum(dw_i / k_i); verify bounds
        assert!(fuse_macs * 3 <= dw_macs);
        assert!(fuse_macs * 5 >= dw_macs);
    }

    #[test]
    #[should_panic(expected = "mask length")]
    fn wrong_mask_length_panics() {
        let base = toy();
        fuse_network(&base, Variant::Half, &Selection::Mask(vec![true]));
    }
}
