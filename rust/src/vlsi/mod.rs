//! VLSI overhead model for ST-OS support (paper §5.2, Table 2).
//!
//! The paper synthesized Bluespec systolic arrays with and without the
//! per-row weight-broadcast links on a proprietary 22 nm library. That flow
//! is unavailable (DESIGN.md §Substitutions #2), so we model the overhead
//! at the component level, in NAND2-equivalent gates:
//!
//! * base PE: 8-bit MAC + operand/accumulator registers + control;
//! * ST-OS additions: a 2:1 weight-input mux per PE, and per row a
//!   broadcast driver whose area/energy grow superlinearly with the wire
//!   span (repeater sizing), plus the dataflow-select control.
//!
//! Constants are calibrated so the 16×16 point lands on the paper's
//! 3.2 % area / 6.7 % power; the 8–64 scaling is then the model's
//! *prediction*, which the tests compare against Table 2.

/// NAND2-equivalent gate counts / relative energy weights.
const A_PE: f64 = 450.0; // MAC8 + 3 operand regs + accumulate reg + ctl
const A_MUX: f64 = 7.6; // 2:1 byte mux on the weight input
const A_DRV: f64 = 0.448; // broadcast driver per row, × span^DRV_EXP
const DRV_EXP: f64 = 1.85; // repeater sizing vs wire length
const A_CTL_PER_ROW: f64 = 26.0; // per-row dataflow select / decoder

const P_PE: f64 = 1.0; // dynamic power per PE (relative)
const P_MUX: f64 = 0.0538;
const P_BCAST: f64 = 0.000117; // per row, × span^P_EXP (wire toggles/cycle)
const P_EXP: f64 = 2.35;
const P_CTL_PER_ROW: f64 = 0.05;

/// Area/power report for one array size.
#[derive(Debug, Clone, Copy)]
pub struct Overhead {
    pub rows: usize,
    pub cols: usize,
    /// Base array (no ST-OS), gate-equivalents / relative power.
    pub base_area: f64,
    pub base_power: f64,
    /// ST-OS additions.
    pub extra_area: f64,
    pub extra_power: f64,
}

impl Overhead {
    pub fn area_pct(&self) -> f64 {
        100.0 * self.extra_area / self.base_area
    }

    pub fn power_pct(&self) -> f64 {
        100.0 * self.extra_power / self.base_power
    }
}

/// Evaluate the model at `rows × cols`.
pub fn st_os_overhead(rows: usize, cols: usize) -> Overhead {
    let (r, c) = (rows as f64, cols as f64);
    let base_area = r * c * A_PE;
    let base_power = r * c * P_PE;
    let extra_area = r * c * A_MUX + r * (A_DRV * c.powf(DRV_EXP) + A_CTL_PER_ROW);
    let extra_power = r * c * P_MUX + r * (P_BCAST * c.powf(P_EXP) + P_CTL_PER_ROW);
    Overhead { rows, cols, base_area, base_power, extra_area, extra_power }
}

/// Table 2's four sizes.
pub fn table2_sizes() -> [usize; 4] {
    [8, 16, 32, 64]
}

/// Paper Table 2 reference values: (size, area %, power %).
pub const PAPER_TABLE2: [(usize, f64, f64); 4] =
    [(8, 3.0, 6.2), (16, 3.2, 6.7), (32, 4.5, 6.4), (64, 5.2, 9.2)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_point_16x16() {
        let o = st_os_overhead(16, 16);
        assert!((o.area_pct() - 3.2).abs() < 0.5, "area {}", o.area_pct());
        assert!((o.power_pct() - 6.7).abs() < 1.0, "power {}", o.power_pct());
    }

    #[test]
    fn matches_table2_within_tolerance() {
        // The paper's own numbers are noisy (power dips at 32×32); accept
        // ±1.6 pp absolute, which preserves the "acceptably small" claim.
        for (s, a, p) in PAPER_TABLE2 {
            let o = st_os_overhead(s, s);
            assert!((o.area_pct() - a).abs() < 1.6, "{s}: area {} vs {a}", o.area_pct());
            assert!((o.power_pct() - p).abs() < 2.2, "{s}: power {} vs {p}", o.power_pct());
        }
    }

    #[test]
    fn area_overhead_grows_with_size() {
        let pcts: Vec<f64> =
            table2_sizes().iter().map(|&s| st_os_overhead(s, s).area_pct()).collect();
        for w in pcts.windows(2) {
            assert!(w[1] > w[0], "not monotone: {pcts:?}");
        }
        // and stays "acceptably small" (paper's conclusion)
        assert!(pcts[3] < 8.0);
    }

    #[test]
    fn overhead_scales_superlinearly_in_cols_only() {
        // widening the array grows the broadcast wire; deepening does not
        let wide = st_os_overhead(16, 64);
        let deep = st_os_overhead(64, 16);
        assert!(wide.area_pct() > deep.area_pct());
    }
}
