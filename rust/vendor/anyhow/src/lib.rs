//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this shim
//! provides the subset of the anyhow API the codebase uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Error values
//! carry a context chain; `{:#}` formatting prints the full chain the way
//! anyhow does, `{}` prints the outermost message only.

use std::fmt;

/// Chained error value. Like anyhow's, it deliberately does NOT implement
/// `std::error::Error` so the blanket `From<E: std::error::Error>` impl
/// cannot conflict with the reflexive `From<Error>`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated (anyhow's format).
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context layers.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg: msgs.pop().unwrap(), source: None };
        while let Some(m) = msgs.pop() {
            err = Error { msg: m, source: Some(Box::new(err)) };
        }
        err
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments (or a printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "no such file");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.root_cause(), "missing key");
        let some: Option<u32> = Some(7);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        let key = "freq";
        let e = anyhow!("missing const {key}");
        assert_eq!(format!("{e}"), "missing const freq");

        fn f(n: usize) -> Result<usize> {
            ensure!(n > 2, "n too small: {n}");
            if n > 100 {
                bail!("n too big: {}", n);
            }
            Ok(n)
        }
        assert!(f(1).is_err());
        assert!(f(200).is_err());
        assert_eq!(f(10).unwrap(), 10);
    }
}
