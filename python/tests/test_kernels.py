"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes/strides/dtypes; this is the build-time contract
that makes the AOT-lowered graphs trustworthy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fuse_conv as K
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 else dict(rtol=2e-5, atol=2e-5)


shape_st = st.tuples(
    st.integers(1, 3),  # batch
    st.sampled_from([2, 4, 6, 8]),  # channels (even for Half)
    st.integers(6, 20),  # H
    st.integers(6, 20),  # W
)


@given(shape=shape_st, k=st.sampled_from([3, 5]), stride=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**16))
def test_fuse_row_matches_ref(shape, k, stride, seed):
    b, c, h, w = shape
    if w < k:
        w = k
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, c, h, w))
    wt = rand(rng, (c, k))
    got = K.fuse_row(x, wt, stride=stride)
    want = R.fuse_row_ref(x, wt, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(np.float32))


@given(shape=shape_st, k=st.sampled_from([3, 5]), stride=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**16))
def test_fuse_col_matches_ref(shape, k, stride, seed):
    b, c, h, w = shape
    if h < k:
        h = k
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, c, h, w))
    wt = rand(rng, (c, k))
    got = K.fuse_col(x, wt, stride=stride)
    want = R.fuse_col_ref(x, wt, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(np.float32))


@given(shape=shape_st, cout=st.sampled_from([1, 3, 8, 17]), seed=st.integers(0, 2**16))
def test_pointwise_matches_ref(shape, cout, seed):
    b, c, h, w = shape
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, c, h, w))
    wt = rand(rng, (c, cout))
    got = K.pointwise(x, wt)
    want = R.pointwise_ref(x, wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@given(shape=shape_st, k=st.sampled_from([3, 5]), stride=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**16))
def test_depthwise_matches_ref(shape, k, stride, seed):
    b, c, h, w = shape
    h, w = max(h, k), max(w, k)
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, c, h, w))
    wt = rand(rng, (c, k, k))
    got = K.depthwise(x, wt, stride=stride)
    want = R.depthwise_ref(x, wt, stride=stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(shape=shape_st, stride=st.sampled_from([1, 2]), full=st.booleans(),
       seed=st.integers(0, 2**16))
def test_fuse_conv_composite_matches_ref(shape, stride, full, seed):
    b, c, h, w = shape
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, c, h, w))
    ch = c if full else c // 2
    wr = rand(rng, (ch, 3))
    wc = rand(rng, (ch, 3))
    got = K.fuse_conv(x, wr, wc, stride=stride, full=full)
    want = R.fuse_conv_ref(x, wr, wc, stride=stride, full=full)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fuse_conv_output_channels():
    rng = np.random.default_rng(0)
    x = rand(rng, (1, 8, 12, 12))
    w4 = rand(rng, (4, 3))
    w8 = rand(rng, (8, 3))
    assert K.fuse_conv(x, w4, w4).shape[1] == 8  # Half keeps C
    assert K.fuse_conv(x, w8, w8, full=True).shape[1] == 16  # Full doubles


def test_fuse_half_parameter_count_is_k_fold_smaller():
    # paper §3.2.1: K²C -> KC
    c, k = 32, 3
    dw = c * k * k
    half = 2 * (c // 2) * k
    assert dw == k * half


def test_bf16_inputs_supported():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 4, 8, 8)).astype(np.float32)).astype(jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)).astype(jnp.bfloat16)
    got = K.fuse_row(x, w)
    want = R.fuse_row_ref(x, w)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.parametrize("stride", [1, 2])
def test_gradients_match_ref_gradients(stride):
    """custom_vjp backward (ref-based) must be consistent with the kernel
    forward: finite-difference check on the loss."""
    rng = np.random.default_rng(3)
    x = rand(rng, (1, 4, 9, 9))
    wr = rand(rng, (2, 3))
    wc = rand(rng, (2, 3))
    op = K.make_fuse_conv(stride=stride)

    def loss(wr):
        return jnp.sum(op(x, wr, wc) ** 2)

    g = jax.grad(loss)(wr)
    eps = 1e-3
    for idx in [(0, 0), (1, 2)]:
        dw = np.zeros_like(np.asarray(wr))
        dw[idx] = eps
        num = (loss(wr + dw) - loss(wr - dw)) / (2 * eps)
        np.testing.assert_allclose(float(num), float(g[idx]), rtol=2e-2, atol=1e-2)


def test_pointwise_large_tile_path():
    # exercise the multi-tile grid (m, n > 128)
    rng = np.random.default_rng(4)
    x = rand(rng, (2, 16, 16, 16))  # m = 512
    w = rand(rng, (16, 160))
    got = K.pointwise(x, w)
    want = R.pointwise_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
