"""NOS scaffold tests: adapter algebra, mask blending, collapse identity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import nos as N
from compile import train as T


def batch(b=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, 3, M.IMAGE_HW, M.IMAGE_HW)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, M.NUM_CLASSES, size=(b,)).astype(np.int32))
    return x, y


def scaffold_with_params(seed=0):
    sc = N.Scaffold()
    tp = [jnp.asarray(p) for p in sc.teacher.init(seed)]
    params = [jnp.asarray(p) for p in sc.init_from_teacher(tp)]
    return sc, tp, params


def test_scaffold_param_count():
    sc = N.Scaffold()
    # K² extra trainable parameters per scaffolded block (paper §4.1)
    assert sc.num_params() == sc.teacher.num_params() + sc.num_blocks * M.KSIZE**2


def test_mask_zero_equals_teacher():
    sc, tp, params = scaffold_with_params()
    x, _ = batch(b=2)
    mask = jnp.zeros((sc.num_blocks,), jnp.float32)
    out_scaffold = sc.apply(params, x, mask)
    out_teacher = sc.teacher.apply(tp, x)
    np.testing.assert_allclose(
        np.asarray(out_scaffold), np.asarray(out_teacher), rtol=1e-4, atol=1e-4
    )


def test_mask_one_equals_collapsed_student():
    sc, tp, params = scaffold_with_params()
    x, _ = batch(b=2, seed=3)
    mask = jnp.ones((sc.num_blocks,), jnp.float32)
    out_scaffold = sc.apply(params, x, mask)
    student_params = sc.collapse(params)
    out_student = sc.student.apply(student_params, x)
    np.testing.assert_allclose(
        np.asarray(out_scaffold), np.asarray(out_student), rtol=1e-4, atol=1e-4
    )


def test_derive_fuse_identity_adapter_extracts_center():
    sc = N.Scaffold()
    c, k = 8, M.KSIZE
    dw = jnp.asarray(np.random.default_rng(1).normal(size=(c, k, k)), jnp.float32)
    w_row, w_col = sc.derive_fuse(dw, jnp.eye(k))
    np.testing.assert_allclose(np.asarray(w_row), np.asarray(dw[: c // 2, :, k // 2]))
    np.testing.assert_allclose(np.asarray(w_col), np.asarray(dw[c // 2 :, k // 2, :]))


def test_derive_fuse_adapter_is_linear():
    sc = N.Scaffold()
    rng = np.random.default_rng(2)
    dw = jnp.asarray(rng.normal(size=(4, 3, 3)), jnp.float32)
    a1 = jnp.asarray(rng.normal(size=(3, 3)), jnp.float32)
    a2 = jnp.asarray(rng.normal(size=(3, 3)), jnp.float32)
    r1, c1 = sc.derive_fuse(dw, a1)
    r2, c2 = sc.derive_fuse(dw, a2)
    rs, cs = sc.derive_fuse(dw, a1 + a2)
    np.testing.assert_allclose(np.asarray(rs), np.asarray(r1 + r2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(c1 + c2), rtol=1e-5)


def test_collapse_shapes_match_student_specs():
    sc, _, params = scaffold_with_params()
    collapsed = sc.collapse(params)
    assert len(collapsed) == len(sc.student.specs)
    for arr, spec in zip(collapsed, sc.student.specs):
        assert tuple(arr.shape) == tuple(spec.shape), spec.name


def test_nos_step_trains_adapters_and_reduces_loss():
    sc, tp, params = scaffold_with_params(seed=4)
    step, n, nt = T.make_nos_step(sc)
    step = jax.jit(step)
    vel = [jnp.zeros_like(p) for p in params]
    x, y = batch(b=8, seed=5)
    mask = jnp.ones((sc.num_blocks,), jnp.float32)
    lr = jnp.float32(0.03)
    losses = []
    adapters_before = np.asarray(params[sc.num_teacher_params])
    for _ in range(6):
        out = step(*params, *vel, *tp, x, y, mask, lr)
        params = list(out[:n])
        vel = list(out[n : 2 * n])
        losses.append(float(out[2 * n]))
    adapters_after = np.asarray(params[sc.num_teacher_params])
    assert losses[-1] < losses[0], losses
    # adapters actually updated (FuSe path active under mask=1)
    assert not np.allclose(adapters_before, adapters_after)


def test_nos_mixed_mask_forward_finite():
    sc, tp, params = scaffold_with_params(seed=6)
    x, _ = batch(b=2, seed=7)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0], jnp.float32)
    out = sc.apply(params, x, mask)
    assert bool(jnp.all(jnp.isfinite(out)))
