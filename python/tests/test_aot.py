"""AOT path tests: HLO text lowering round-trips through the XLA client
(the same parser the Rust runtime uses) and the manifest is consistent."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M
from compile import train as T

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrips_locally():
    """Lower a small fn to HLO text, re-parse and execute it with the
    local CPU client — validating the exact interchange format."""
    def fn(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(fn, [spec, spec])
    assert "HloModule" in text

    # the same text parser the Rust runtime's HloModuleProto::from_text uses
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_infer_graph_numerics_match_eager():
    """The lowered student_infer graph computes the same logits as the
    eager model."""
    net = M.student()
    fn, n = T.make_infer(net)
    params = [jnp.asarray(p) for p in net.init(2)]
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.normal(size=(aot.INFER_B, 3, M.IMAGE_HW, M.IMAGE_HW)).astype(np.float32)
    )
    eager = net.apply(params, x)
    jitted = jax.jit(fn)(*params, x)[0]
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-4, atol=1e-4)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestArtifacts:
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
            return f.read().splitlines()

    def test_manifest_lists_all_graphs(self):
        lines = self.manifest()
        graphs = [l.split()[1] for l in lines if l.startswith("graph ")]
        for expect in [
            "teacher_train_step",
            "student_train_step",
            "nos_train_step",
            "collapse",
            "student_infer",
            "teacher_infer",
            "feature_teacher",
            "feature_student",
        ]:
            assert expect in graphs, f"missing graph {expect}"

    def test_all_hlo_files_exist_and_parse(self):
        lines = self.manifest()
        for l in lines:
            if l.startswith("graph "):
                fname = l.split()[2]
                path = os.path.join(ARTIFACTS, fname)
                assert os.path.exists(path), fname
                with open(path) as f:
                    text = f.read()
                assert text.startswith("HloModule"), fname
                # parse with the same entry point the Rust runtime uses
                assert xc._xla.hlo_module_from_text(text) is not None

    def test_init_bins_match_spec_sizes(self):
        teacher = M.teacher()
        student = M.student()
        tb = os.path.getsize(os.path.join(ARTIFACTS, "teacher_init.bin"))
        sb = os.path.getsize(os.path.join(ARTIFACTS, "student_init.bin"))
        assert tb == 4 * teacher.num_params()
        assert sb == 4 * student.num_params()

    def test_manifest_consts_consistent(self):
        lines = self.manifest()
        consts = {
            l.split()[1]: l.split()[2] for l in lines if l.startswith("const ")
        }
        assert int(consts["num_teacher_params"]) == len(M.teacher().specs)
        assert int(consts["num_student_params"]) == len(M.student().specs)
        assert int(consts["image_hw"]) == M.IMAGE_HW
        assert int(consts["num_blocks"]) == len(M.teacher().blocks)
