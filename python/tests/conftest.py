"""Collection guards for optional heavy dependencies.

The CI python job (and local runs in minimal environments) must not fail
at collection time when JAX or hypothesis is absent: every module here
imports jax at module scope, and test_kernels additionally needs
hypothesis. Skip collecting what cannot import; pytest still runs (and
reports) whatever remains.
"""

import importlib.util
import sys
from pathlib import Path

# Make `from compile... import ...` work regardless of invocation cwd.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def _missing(mod: str) -> bool:
    return importlib.util.find_spec(mod) is None


collect_ignore = []
if _missing("jax"):
    collect_ignore += [
        "test_aot.py",
        "test_kernels.py",
        "test_model.py",
        "test_nos.py",
    ]
elif _missing("hypothesis"):
    collect_ignore += ["test_kernels.py"]
