"""L2 model tests: shapes, parameter bookkeeping, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import train as T


def batch(b=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, 3, M.IMAGE_HW, M.IMAGE_HW)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, M.NUM_CLASSES, size=(b,)).astype(np.int32))
    return x, y


def test_teacher_and_student_share_macro_architecture():
    t, s = M.teacher(), M.student()
    assert len(t.blocks) == len(s.blocks) == 7
    for bt, bs in zip(t.blocks, s.blocks):
        assert (bt.cin, bt.cout, bt.stride) == (bs.cin, bs.cout, bs.stride)


def test_student_has_fewer_params_than_teacher():
    # FuSe-Half replaces K²C dw params with KC
    t, s = M.teacher(), M.student()
    assert s.num_params() < t.num_params()
    dw_params = sum(
        np.prod(sp.shape) for sp in t.specs if sp.name.endswith(".dw")
    )
    fuse_params = sum(
        np.prod(sp.shape) for sp in s.specs if "fuse" in sp.name
    )
    assert fuse_params * M.KSIZE == dw_params


def test_forward_shapes():
    x, _ = batch(b=2)
    for net in (M.teacher(), M.student()):
        params = [jnp.asarray(p) for p in net.init(0)]
        logits = net.apply(params, x)
        assert logits.shape == (2, M.NUM_CLASSES)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_feature_block_hook():
    x, _ = batch(b=1)
    net = M.teacher()
    params = [jnp.asarray(p) for p in net.init(0)]
    f = net.apply(params, x, feature_block=3)
    # block 3 is the first stride-2 block of stage 3: 8x8 spatial, 32 ch
    assert f.shape[0] == 1
    assert f.ndim == 4


def test_init_deterministic():
    net = M.teacher()
    a = net.init(7)
    b = net.init(7)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = net.init(8)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c))


def test_plain_train_step_reduces_loss():
    net = M.student()
    step, n = T.make_plain_step(net)
    step = jax.jit(step)
    params = [jnp.asarray(p) for p in net.init(0)]
    vel = [jnp.zeros_like(p) for p in params]
    x, y = batch(b=8, seed=1)
    lr = jnp.float32(0.05)
    losses = []
    for _ in range(8):
        out = step(*params, *vel, x, y, lr)
        params = list(out[:n])
        vel = list(out[n : 2 * n])
        losses.append(float(out[2 * n]))
    # same batch: loss must fall substantially
    assert losses[-1] < losses[0] * 0.9, losses


def test_accuracy_metric():
    logits = jnp.asarray([[2.0, 1.0], [0.0, 3.0]])
    labels = jnp.asarray([0, 1], dtype=jnp.int32)
    assert float(T.accuracy(logits, labels)) == 1.0
    labels = jnp.asarray([1, 1], dtype=jnp.int32)
    assert float(T.accuracy(logits, labels)) == 0.5


def test_cross_entropy_sane():
    logits = jnp.zeros((4, 10))
    labels = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    ce = float(T.cross_entropy(logits, labels))
    np.testing.assert_allclose(ce, np.log(10.0), rtol=1e-6)


def test_kd_loss_zero_when_identical():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 10)), jnp.float32)
    assert abs(float(T.kd_loss(logits, logits))) < 1e-6
    other = logits + 1.0  # uniform shift leaves softmax unchanged
    assert abs(float(T.kd_loss(other, logits))) < 1e-5
