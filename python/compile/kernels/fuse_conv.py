"""Layer-1 Pallas kernels for FuSeConv (paper §3.1) and its neighbours.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hardware
story is a 16×16 systolic array with the ST-OS dataflow — each independent
1D convolution occupies one array row with a broadcast weight. The TPU
analogue we express with Pallas is: *grid over (batch, channel)* so each
grid step is one "systolic row's" worth of independent 1D convolutions,
with the channel's full spatial plane staged in VMEM (BlockSpec) and the
K-tap reduction unrolled — a broadcastable scalar weight per tap, exactly
the ST-OS weight-broadcast structure. Pointwise (1×1) convolution is the
MXU-shaped matmul and is tiled accordingly.

All kernels run with ``interpret=True``: real Mosaic lowering emits a TPU
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO so the same graph runs under the Rust runtime. Correctness is
pinned against ``ref.py`` by ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# FuSe 1D convolutions
# ---------------------------------------------------------------------------



# Channel-tile selection: stage (B, ct, H, W) blocks in VMEM, keeping the
# block under ~2 MiB (the TPU VMEM-budget heuristic; on CPU-interpret this
# also bounds the grid length, which dominates wallclock).
_VMEM_BUDGET = 2 * 1024 * 1024


def _channel_tile(b: int, c: int, h: int, w: int, bytes_per: int = 4) -> int:
    per_channel = b * h * w * bytes_per
    ct = max(1, _VMEM_BUDGET // max(per_channel, 1))
    return min(c, ct)


def _fuse_row_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int):
    """(B, CT, H, W) block: 1xK conv along width for CT channels at once.

    The K-tap loop is unrolled; each tap is a per-channel broadcast weight
    times a strided slice — the software image of ST-OS's row-broadcast.
    """
    x = x_ref[...]
    b, ct, h, w_out = o_ref.shape
    acc = jnp.zeros((b, ct, h, w_out), dtype=jnp.float32)
    for t in range(k):
        sl = jax.lax.slice(
            x, (0, 0, 0, t), (b, ct, h, t + 1 + (w_out - 1) * stride), (1, 1, 1, stride)
        )
        acc = acc + w_ref[:, t][None, :, None, None].astype(jnp.float32) * sl.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _fuse_col_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int):
    """(B, CT, H, W) block: Kx1 conv along height for CT channels at once."""
    x = x_ref[...]
    b, ct, h_out, w = o_ref.shape
    acc = jnp.zeros((b, ct, h_out, w), dtype=jnp.float32)
    for t in range(k):
        sl = jax.lax.slice(
            x, (0, 0, t, 0), (b, ct, t + 1 + (h_out - 1) * stride, w), (1, 1, stride, 1)
        )
        acc = acc + w_ref[:, t][None, :, None, None].astype(jnp.float32) * sl.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _conv1d_out(n: int, k: int, stride: int) -> int:
    return (n - k) // stride + 1


@functools.partial(jax.jit, static_argnames=("stride",))
def fuse_row(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Row half of FuSeConv: x (B, C, H, W) ⊛ w (C, K) → (B, C, H', W').

    VALID padding along the filter axis; the caller pads (the L2 model pads
    SAME, and subsamples rows for stride along the orthogonal axis).
    """
    b, c, h, w_in = x.shape
    c2, k = w.shape
    assert c == c2, f"channels {c} vs filters {c2}"
    w_out = _conv1d_out(w_in, k, stride)
    h_out = _conv1d_out(h, 1, stride)  # orthogonal axis subsampling
    xs = x[:, :, :: stride, :] if stride > 1 else x
    out_shape = jax.ShapeDtypeStruct((b, c, h_out, w_out), x.dtype)
    ct = _channel_tile(b, c, h_out, w_in)
    return pl.pallas_call(
        functools.partial(_fuse_row_kernel, k=k, stride=stride),
        grid=(pl.cdiv(c, ct),),
        in_specs=[
            pl.BlockSpec((b, ct, h_out, w_in), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((ct, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, ct, h_out, w_out), lambda j: (0, j, 0, 0)),
        out_shape=out_shape,
        interpret=True,
    )(xs, w)


@functools.partial(jax.jit, static_argnames=("stride",))
def fuse_col(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Column half of FuSeConv: x (B, C, H, W) ⊛ w (C, K) → (B, C, H', W')."""
    b, c, h, w_in = x.shape
    c2, k = w.shape
    assert c == c2
    h_out = _conv1d_out(h, k, stride)
    w_out = _conv1d_out(w_in, 1, stride)
    xs = x[:, :, :, ::stride] if stride > 1 else x
    out_shape = jax.ShapeDtypeStruct((b, c, h_out, w_out), x.dtype)
    ct = _channel_tile(b, c, h, w_out)
    return pl.pallas_call(
        functools.partial(_fuse_col_kernel, k=k, stride=stride),
        grid=(pl.cdiv(c, ct),),
        in_specs=[
            pl.BlockSpec((b, ct, h, w_out), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((ct, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, ct, h_out, w_out), lambda j: (0, j, 0, 0)),
        out_shape=out_shape,
        interpret=True,
    )(xs, w)


# ---------------------------------------------------------------------------
# Pointwise (1×1) convolution — the MXU-shaped GEMM
# ---------------------------------------------------------------------------

# MXU-friendly tiles: multiples of (8, 128) systolic geometry, shrunk when
# the problem is smaller.
def _tile(n: int, pref: int) -> int:
    return min(pref, n)


def _pointwise_kernel(x_ref, w_ref, o_ref):
    """x (M_t, Cin) @ w (Cin, N_t) in fp32 accumulation."""
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@jax.jit
def pointwise(x: jax.Array, w: jax.Array) -> jax.Array:
    """1×1 convolution: x (B, C, H, W), w (C, C') → (B, C', H, W)."""
    b, c, h, wd = x.shape
    c2, cout = w.shape
    assert c == c2
    m = b * h * wd
    xm = jnp.transpose(x, (0, 2, 3, 1)).reshape(m, c)
    mt = _tile(m, 128)
    nt = _tile(cout, 128)
    grid = (pl.cdiv(m, mt), pl.cdiv(cout, nt))
    om = pl.pallas_call(
        _pointwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mt, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, nt), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, cout), x.dtype),
        interpret=True,
    )(xm, w)
    return jnp.transpose(om.reshape(b, h, wd, cout), (0, 3, 1, 2))


# ---------------------------------------------------------------------------
# Depthwise K×K — the teacher operator (baseline + NOS teacher)
# ---------------------------------------------------------------------------


def _depthwise_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int):
    """(B, CT, H, W) block: KxK depthwise conv for CT channels at once."""
    x = x_ref[...]
    b, ct, h_out, w_out = o_ref.shape
    acc = jnp.zeros((b, ct, h_out, w_out), dtype=jnp.float32)
    for dy in range(k):
        for dx in range(k):
            sl = jax.lax.slice(
                x,
                (0, 0, dy, dx),
                (b, ct, dy + 1 + (h_out - 1) * stride, dx + 1 + (w_out - 1) * stride),
                (1, 1, stride, stride),
            )
            acc = acc + w_ref[:, dy, dx][None, :, None, None].astype(jnp.float32) * sl.astype(
                jnp.float32
            )
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride",))
def depthwise(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Depthwise conv: x (B, C, H, W), w (C, K, K) → (B, C, H', W')."""
    b, c, h, wd = x.shape
    c2, k, k2 = w.shape
    assert c == c2 and k == k2
    h_out = _conv1d_out(h, k, stride)
    w_out = _conv1d_out(wd, k, stride)
    ct = _channel_tile(b, c, h, wd)
    return pl.pallas_call(
        functools.partial(_depthwise_kernel, k=k, stride=stride),
        grid=(pl.cdiv(c, ct),),
        in_specs=[
            pl.BlockSpec((b, ct, h, wd), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((ct, k, k), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, ct, h_out, w_out), lambda j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, h_out, w_out), x.dtype),
        interpret=True,
    )(x, w)


# ---------------------------------------------------------------------------
# FuSeConv composite (Half / Full variants, SAME padding)
# ---------------------------------------------------------------------------


def _same_pad_w(x, k):
    lo = (k - 1) // 2
    return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (lo, k - 1 - lo)))


def _same_pad_h(x, k):
    lo = (k - 1) // 2
    return jnp.pad(x, ((0, 0), (0, 0), (lo, k - 1 - lo), (0, 0)))


def fuse_conv(x: jax.Array, w_row: jax.Array, w_col: jax.Array, stride: int = 1,
              full: bool = False) -> jax.Array:
    """The FuSeConv operator (paper Fig 4a), SAME padding.

    Half (default): row filters act on the first C/2 channels, column
    filters on the rest → C output channels. Full: both act on all C
    channels → 2C output channels.
    """
    b, c, h, wd = x.shape
    if full:
        xr, xc = x, x
    else:
        assert c % 2 == 0, "FuSe-Half needs even channels"
        xr, xc = x[:, : c // 2], x[:, c // 2 :]
    kr = w_row.shape[1]
    kc = w_col.shape[1]
    r = fuse_row(_same_pad_w(xr, kr), w_row, stride=stride)
    cc = fuse_col(_same_pad_h(xc, kc), w_col, stride=stride)
    return jnp.concatenate([r, cc], axis=1)


# ---------------------------------------------------------------------------
# Differentiable wrappers (L2 training path)
#
# Interpret-mode pallas_call has no reverse-mode rule, so each kernel gets a
# custom VJP: forward runs the Pallas kernel, backward is the vjp of the
# pure-jnp oracle in ref.py (pytest pins kernel == ref, so the gradient is
# consistent with the forward to numerical tolerance). The backward ops are
# plain XLA convolutions — fine for the AOT-lowered train-step graphs.
# ---------------------------------------------------------------------------

from compile.kernels import ref as _ref  # noqa: E402


def make_fuse_conv(stride: int = 1, full: bool = False):
    """Differentiable FuSeConv(x, w_row, w_col) for fixed (stride, full)."""

    def _ref_fn(x, wr, wc):
        return _ref.fuse_conv_ref(x, wr, wc, stride=stride, full=full)

    @jax.custom_vjp
    def op(x, wr, wc):
        return fuse_conv(x, wr, wc, stride=stride, full=full)

    def fwd(x, wr, wc):
        return op(x, wr, wc), (x, wr, wc)

    def bwd(res, g):
        x, wr, wc = res
        _, vjp = jax.vjp(_ref_fn, x, wr, wc)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def make_depthwise(stride: int = 1):
    """Differentiable depthwise(x, w) with SAME padding for fixed stride."""

    def _pad(x, k):
        lo = (k - 1) // 2
        return jnp.pad(x, ((0, 0), (0, 0), (lo, k - 1 - lo), (lo, k - 1 - lo)))

    def _ref_fn(x, w):
        return _ref.depthwise_ref(_pad(x, w.shape[-1]), w, stride=stride)

    @jax.custom_vjp
    def op(x, w):
        return depthwise(_pad(x, w.shape[-1]), w, stride=stride)

    def fwd(x, w):
        return op(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        _, vjp = jax.vjp(_ref_fn, x, w)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


@jax.custom_vjp
def pointwise_ad(x, w):
    """Differentiable pointwise(x, w)."""
    return pointwise(x, w)


def _pw_fwd(x, w):
    return pointwise_ad(x, w), (x, w)


def _pw_bwd(res, g):
    x, w = res
    _, vjp = jax.vjp(_ref.pointwise_ref, x, w)
    return vjp(g)


pointwise_ad.defvjp(_pw_fwd, _pw_bwd)
