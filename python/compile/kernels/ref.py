"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract — pytest asserts allclose between kernels and these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fuse_row_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """1×K conv along width, per channel. x (B,C,H,W), w (C,K) — VALID."""
    b, c, h, wd = x.shape
    _, k = w.shape
    xs = x[:, :, ::stride, :] if stride > 1 else x
    # grouped conv with feature_group_count = C
    rhs = w[:, None, None, :]  # (C, 1, 1, K) => OIHW with O=C, I=1
    return jax.lax.conv_general_dilated(
        xs.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(1, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    ).astype(x.dtype)


def fuse_col_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """K×1 conv along height, per channel."""
    b, c, h, wd = x.shape
    _, k = w.shape
    xs = x[:, :, :, ::stride] if stride > 1 else x
    rhs = w[:, None, :, None]  # (C, 1, K, 1)
    return jax.lax.conv_general_dilated(
        xs.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(stride, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    ).astype(x.dtype)


def pointwise_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """1×1 conv. x (B,C,H,W), w (C,C')."""
    return jnp.einsum(
        "bchw,cd->bdhw", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def depthwise_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """K×K depthwise, VALID. x (B,C,H,W), w (C,K,K)."""
    c = x.shape[1]
    rhs = w[:, None, :, :]
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c,
    ).astype(x.dtype)


def fuse_conv_ref(x, w_row, w_col, stride: int = 1, full: bool = False):
    """Composite FuSeConv with SAME padding — mirrors kernels.fuse_conv."""
    b, c, h, wd = x.shape
    if full:
        xr, xc = x, x
    else:
        xr, xc = x[:, : c // 2], x[:, c // 2 :]
    kr, kc = w_row.shape[1], w_col.shape[1]
    lo_r = (kr - 1) // 2
    lo_c = (kc - 1) // 2
    xr = jnp.pad(xr, ((0, 0), (0, 0), (0, 0), (lo_r, kr - 1 - lo_r)))
    xc = jnp.pad(xc, ((0, 0), (0, 0), (lo_c, kc - 1 - lo_c), (0, 0)))
    r = fuse_row_ref(xr, w_row, stride=stride)
    cc = fuse_col_ref(xc, w_col, stride=stride)
    return jnp.concatenate([r, cc], axis=1)
