"""Neural Operator Scaffolding (paper §4.1) in JAX.

The scaffold holds, per bottleneck block, the *teacher* depthwise kernel
``T_w ∈ R^{C×K×K}`` plus one shared K×K adapter ``A`` (the paper uses the
same matrix for row and column filters, shared across all filters of the
layer — K² extra parameters per block). The FuSe student weights are the
linear projections

    R_w[c] = A · T_w[c, :, mid]     (row filter, channel c)
    C_w[c] = A · T_w[c, mid, :]     (column filter, channel c)

Training samples each scaffolded block as depthwise or FuSe (the OFA-style
schedule); the sampling mask arrives as a runtime input so the AOT graph
is sampled by the Rust coordinator. After training, ``collapse`` folds the
adapters in and discards the scaffold — inference runs pure FuSeConv.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import fuse_conv as kernels

KSIZE = M.KSIZE


class Scaffold:
    """Parameter layout: the teacher EdgeNet's specs + one adapter/block."""

    def __init__(self):
        self.teacher = M.teacher()
        self.student = M.student()
        self.specs = list(self.teacher.specs) + [
            M.ParamSpec(f"b{b.index}.adapter", (KSIZE, KSIZE))
            for b in self.teacher.blocks
        ]
        self.num_teacher_params = len(self.teacher.specs)
        self.num_blocks = len(self.teacher.blocks)

    def num_params(self) -> int:
        return sum(s.size for s in self.specs)

    def init_from_teacher(self, teacher_params: list) -> list:
        """Scaffold init: copy the (pre)trained teacher, identity adapters."""
        assert len(teacher_params) == self.num_teacher_params
        adapters = [np.eye(KSIZE, dtype=np.float32) for _ in range(self.num_blocks)]
        return list(teacher_params) + adapters

    # -- weight derivation ----------------------------------------------------

    def derive_fuse(self, dw_w: jax.Array, adapter: jax.Array):
        """(C,K,K) teacher kernel + (K,K) adapter → row (C/2,K), col (C/2,K).

        Row filters come from the first C/2 channels' centre columns, column
        filters from the other C/2 channels' centre rows (FuSe-Half split).
        """
        c = dw_w.shape[0]
        mid = KSIZE // 2
        rows = dw_w[: c // 2, :, mid]  # (C/2, K): centre column per channel
        cols = dw_w[c // 2 :, mid, :]  # (C/2, K): centre row per channel
        w_row = rows @ adapter.T  # R_w[c] = A · T_w[c,:,mid]
        w_col = cols @ adapter.T
        return w_row, w_col

    # -- forward ---------------------------------------------------------------

    def apply(self, params: list, x: jax.Array, mask: jax.Array,
              feature_block: int | None = None):
        """Scaffolded forward. ``mask``: (num_blocks,) in [0,1] — 1 selects
        the FuSe path of that block, 0 the depthwise path (training samples
        hard 0/1; the blend keeps the graph static)."""
        assert len(params) == len(self.specs)
        tp = params[: self.num_teacher_params]
        adapters = params[self.num_teacher_params :]

        net = self.teacher
        cur = [0]
        take = lambda: net._take(tp, cur)  # noqa: E731

        stem_w = take()
        h = jax.lax.conv_general_dilated(
            x, stem_w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )
        h = jax.nn.relu(M.instance_norm(h))
        for b in net.blocks:
            y = h
            if b.expand != b.cin:
                w = take()
                bias = take()
                y = M.instance_norm(kernels.pointwise_ad(y, w)) + bias[None, :, None, None]
                y = jax.nn.relu(y)
            dw_w = take()
            # both paths, blended by the sampled mask
            dw_op = kernels.make_depthwise(stride=b.stride)
            out_dw = dw_op(y, dw_w)
            w_row, w_col = self.derive_fuse(dw_w, adapters[b.index])
            fuse_op = kernels.make_fuse_conv(stride=b.stride, full=False)
            out_fuse = fuse_op(y, w_row, w_col)
            m = mask[b.index]
            y = m * out_fuse + (1.0 - m) * out_dw
            scale = take()
            bias = take()
            y = M.instance_norm(y) * scale[None, :, None, None] + bias[None, :, None, None]
            y = jax.nn.relu(y)
            w = take()
            pb = take()
            y = kernels.pointwise_ad(y, w) + pb[None, :, None, None]
            if b.residual:
                y = y + h
            h = y
            if feature_block is not None and b.index == feature_block:
                return h
        w = take()
        hb = take()
        h = jax.nn.relu(M.instance_norm(kernels.pointwise_ad(h, w)) + hb[None, :, None, None])
        h = jnp.mean(h, axis=(2, 3))
        w = take()
        fb = take()
        return h @ w + fb

    # -- collapse ---------------------------------------------------------------

    def collapse(self, params: list) -> list:
        """Fold adapters into standalone FuSe-student parameters (the
        "remove the scaffold" step). Returns params in student spec order."""
        assert len(params) == len(self.specs)
        tp = list(params[: self.num_teacher_params])
        adapters = params[self.num_teacher_params :]
        out = []
        ti = 0
        # teacher and student specs walk in lockstep; dw kernels expand
        # into (row, col) pairs.
        for spec in self.teacher.specs:
            v = tp[ti]
            if spec.name.endswith(".dw"):
                block = int(spec.name.split(".")[0][1:])
                w_row, w_col = self.derive_fuse(jnp.asarray(v), jnp.asarray(adapters[block]))
                out.append(w_row)
                out.append(w_col)
            else:
                out.append(jnp.asarray(v))
            ti += 1
        assert len(out) == len(self.student.specs)
        return out
