"""AOT export: lower every L2 graph to HLO **text** + a manifest the Rust
runtime parses. Python runs only here (``make artifacts``); the request
path is pure Rust + PJRT.

HLO text — NOT ``lowered.compiler_ir().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import nos as N
from compile import train as T

TRAIN_B = 16
INFER_B = 8
FEATURE_BLOCK = 3  # paper Fig 12 visualizes the 3rd mobile bottleneck


def to_hlo_text(fn, example_args) -> str:
    # keep_unused: the feature-extraction graphs read only a prefix of the
    # parameter list; the Rust runtime feeds the full set positionally, so
    # unused arguments must stay in the HLO signature.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_args(specs):
    return [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in specs]


def data_args(batch):
    x = jax.ShapeDtypeStruct((batch, 3, M.IMAGE_HW, M.IMAGE_HW), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y


class Manifest:
    def __init__(self):
        self.lines = []

    def const(self, key, value):
        self.lines.append(f"const {key} {value}")

    def begin_graph(self, name, filename):
        self.lines.append(f"graph {name} {filename}")

    def io(self, kind, aval):
        dims = "x".join(str(d) for d in aval.shape) if aval.shape else "scalar"
        dt = {jnp.float32: "f32", jnp.int32: "i32"}.get(aval.dtype.type, str(aval.dtype))
        self.lines.append(f"  {kind} {dt} {dims}")

    def params_block(self, label, specs):
        self.lines.append(f"params {label} {len(specs)}")
        for s in specs:
            dims = "x".join(str(d) for d in s.shape)
            self.lines.append(f"  p {s.name} {dims}")

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def export_graph(man: Manifest, outdir: str, name: str, fn, args):
    text = to_hlo_text(fn, args)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    man.begin_graph(name, fname)
    for a in args:
        man.io("in", a)
    # output avals from an abstract eval
    out = jax.eval_shape(fn, *args)
    for o in jax.tree_util.tree_leaves(out):
        man.io("out", o)
    print(f"  wrote {fname} ({len(text) / 1e6:.1f} MB, {len(args)} inputs)")


def write_init(path: str, params: list):
    flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
    flat.tofile(path)
    print(f"  wrote {os.path.basename(path)} ({flat.nbytes / 1e3:.0f} kB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)

    teacher = M.teacher()
    student = M.student()
    scaffold = N.Scaffold()

    man = Manifest()
    man.const("image_hw", M.IMAGE_HW)
    man.const("num_classes", M.NUM_CLASSES)
    man.const("train_batch", TRAIN_B)
    man.const("infer_batch", INFER_B)
    man.const("num_blocks", len(teacher.blocks))
    man.const("ksize", M.KSIZE)
    man.const("feature_block", FEATURE_BLOCK)
    man.const("num_teacher_params", len(teacher.specs))
    man.const("num_student_params", len(student.specs))
    man.const("num_scaffold_params", len(scaffold.specs))
    man.params_block("teacher", teacher.specs)
    man.params_block("student", student.specs)
    man.params_block("scaffold", scaffold.specs)

    lr = jax.ShapeDtypeStruct((), jnp.float32)
    x, y = data_args(TRAIN_B)
    mask = jax.ShapeDtypeStruct((len(teacher.blocks),), jnp.float32)

    print("exporting graphs:")
    step, n = T.make_plain_step(teacher)
    export_graph(man, outdir, "teacher_train_step", step,
                 spec_args(teacher.specs) * 2 + [x, y, lr])

    step, n = T.make_plain_step(student)
    export_graph(man, outdir, "student_train_step", step,
                 spec_args(student.specs) * 2 + [x, y, lr])

    step, n, nt = T.make_nos_step(scaffold)
    export_graph(man, outdir, "nos_train_step", step,
                 spec_args(scaffold.specs) * 2 + spec_args(teacher.specs)
                 + [x, y, mask, lr])

    fn, n = T.make_collapse(scaffold)
    export_graph(man, outdir, "collapse", fn, spec_args(scaffold.specs))

    xi, _ = data_args(INFER_B)
    fn, n = T.make_infer(student)
    export_graph(man, outdir, "student_infer", fn, spec_args(student.specs) + [xi])
    fn, n = T.make_infer(teacher)
    export_graph(man, outdir, "teacher_infer", fn, spec_args(teacher.specs) + [xi])

    x1 = jax.ShapeDtypeStruct((1, 3, M.IMAGE_HW, M.IMAGE_HW), jnp.float32)
    fn, n = T.make_feature(teacher, FEATURE_BLOCK)
    export_graph(man, outdir, "feature_teacher", fn, spec_args(teacher.specs) + [x1])
    fn, n = T.make_feature(student, FEATURE_BLOCK)
    export_graph(man, outdir, "feature_student", fn, spec_args(student.specs) + [x1])

    write_init(os.path.join(outdir, "teacher_init.bin"), teacher.init(seed=1))
    write_init(os.path.join(outdir, "student_init.bin"), student.init(seed=2))
    man.write(os.path.join(outdir, "manifest.txt"))
    print(f"manifest: {os.path.join(outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
