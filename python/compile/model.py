"""Layer-2 JAX model: *EdgeNet*, a MobileNetV2-style bottleneck classifier
for 32×32 inputs used by the end-to-end training/serving experiments
(DESIGN.md S9, substitution #1 — ImageNet-scale nets are infeasible here,
and the paper's accuracy claims are *trends*, which reproduce at this
scale).

The network exists in two operator variants sharing the same macro
architecture, exactly like the paper's in-place replacement:

* ``variant="dw"``   — depthwise K×K bottlenecks (the teacher / baseline);
* ``variant="fuse"`` — FuSe-Half row/column bottlenecks (the student).

Parameters are a flat ``list`` of arrays with a deterministic spec so the
Rust runtime can allocate, initialize, and feed them positionally through
the AOT-compiled HLO graphs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import fuse_conv as kernels


def instance_norm(y: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-sample, per-channel spatial standardization (BN-free nets train
    poorly at depth; instance norm is stateless, so the AOT train/infer
    graphs need no running statistics)."""
    mu = jnp.mean(y, axis=(2, 3), keepdims=True)
    var = jnp.var(y, axis=(2, 3), keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps)

# (expansion t, channels c, repeats n, first-stride s) — V2-style stages
# sized for 32×32 inputs.
STAGES = ((1, 16, 1, 1), (4, 24, 2, 2), (4, 32, 2, 2), (4, 64, 2, 2))
STEM_C = 16
HEAD_C = 128
NUM_CLASSES = 10
KSIZE = 3
IMAGE_HW = 32


@dataclass
class ParamSpec:
    name: str
    shape: tuple

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class BlockCfg:
    index: int
    cin: int
    cout: int
    expand: int  # expanded channel count
    stride: int
    residual: bool


@dataclass
class EdgeNet:
    """EdgeNet definition. ``variant``: "dw" (teacher) or "fuse" (student)."""

    variant: str = "dw"
    blocks: list = field(default_factory=list)
    specs: list = field(default_factory=list)

    def __post_init__(self):
        assert self.variant in ("dw", "fuse")
        cin = STEM_C
        idx = 0
        for t, c, n, s in STAGES:
            for rep in range(n):
                stride = s if rep == 0 else 1
                self.blocks.append(
                    BlockCfg(
                        index=idx,
                        cin=cin,
                        cout=c,
                        expand=cin * t,
                        stride=stride,
                        residual=(stride == 1 and cin == c),
                    )
                )
                cin = c
                idx += 1
        self.specs = self._build_specs()

    # -- parameter bookkeeping ------------------------------------------------

    def _op_specs(self, b: BlockCfg) -> list:
        k = KSIZE
        if self.variant == "dw":
            return [ParamSpec(f"b{b.index}.dw", (b.expand, k, k))]
        half = b.expand // 2
        return [
            ParamSpec(f"b{b.index}.fuse_row", (half, k)),
            ParamSpec(f"b{b.index}.fuse_col", (half, k)),
        ]

    def _build_specs(self) -> list:
        specs = [ParamSpec("stem.w", (STEM_C, 3, KSIZE, KSIZE))]
        for b in self.blocks:
            if b.expand != b.cin:
                specs.append(ParamSpec(f"b{b.index}.expand", (b.cin, b.expand)))
                specs.append(ParamSpec(f"b{b.index}.expand_b", (b.expand,)))
            specs.extend(self._op_specs(b))
            specs.append(ParamSpec(f"b{b.index}.op_scale", (b.expand,)))
            specs.append(ParamSpec(f"b{b.index}.op_bias", (b.expand,)))
            specs.append(ParamSpec(f"b{b.index}.project", (b.expand, b.cout)))
            specs.append(ParamSpec(f"b{b.index}.project_b", (b.cout,)))
        specs.append(ParamSpec("head.w", (self.blocks[-1].cout, HEAD_C)))
        specs.append(ParamSpec("head.b", (HEAD_C,)))
        specs.append(ParamSpec("fc.w", (HEAD_C, NUM_CLASSES)))
        specs.append(ParamSpec("fc.b", (NUM_CLASSES,)))
        return specs

    def num_params(self) -> int:
        return sum(s.size for s in self.specs)

    def init(self, seed: int = 0) -> list:
        """He-style init, deterministic in `seed`; returns list of f32."""
        rng = np.random.default_rng(seed)
        out = []
        for s in self.specs:
            if s.name.endswith(("_b", ".b", "op_bias")):
                out.append(np.zeros(s.shape, np.float32))
            elif s.name.endswith("op_scale"):
                out.append(np.ones(s.shape, np.float32))
            else:
                fan_in = int(np.prod(s.shape[:-1])) if len(s.shape) > 1 else s.shape[0]
                std = float(np.sqrt(2.0 / max(fan_in, 1)))
                out.append(rng.normal(0.0, std, s.shape).astype(np.float32))
        return out

    # -- forward ---------------------------------------------------------------

    def _take(self, params: list, cursor: list) -> jax.Array:
        v = params[cursor[0]]
        cursor[0] += 1
        return v

    def apply(self, params: list, x: jax.Array, feature_block: int | None = None):
        """Forward pass. x: (B, 3, 32, 32) → logits (B, 10).

        With ``feature_block = i``, returns the block-i output feature map
        instead (the Fig 12 visualization hook).
        """
        assert len(params) == len(self.specs), (
            f"got {len(params)} params, expected {len(self.specs)}"
        )
        cur = [0]
        stem_w = self._take(params, cur)
        x = jax.lax.conv_general_dilated(
            x, stem_w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )
        x = jax.nn.relu(instance_norm(x))
        for b in self.blocks:
            y = x
            if b.expand != b.cin:
                w = self._take(params, cur)
                bias = self._take(params, cur)
                y = instance_norm(kernels.pointwise_ad(y, w)) + bias[None, :, None, None]
                y = jax.nn.relu(y)
            if self.variant == "dw":
                wd = self._take(params, cur)
                op = kernels.make_depthwise(stride=b.stride)
                y = op(y, wd)
            else:
                wr = self._take(params, cur)
                wc = self._take(params, cur)
                op = kernels.make_fuse_conv(stride=b.stride, full=False)
                y = op(y, wr, wc)
            scale = self._take(params, cur)
            bias = self._take(params, cur)
            y = instance_norm(y) * scale[None, :, None, None] + bias[None, :, None, None]
            y = jax.nn.relu(y)
            w = self._take(params, cur)
            pb = self._take(params, cur)
            y = kernels.pointwise_ad(y, w) + pb[None, :, None, None]
            if b.residual:
                y = y + x
            x = y
            if feature_block is not None and b.index == feature_block:
                return x
        w = self._take(params, cur)
        hb = self._take(params, cur)
        x = jax.nn.relu(instance_norm(kernels.pointwise_ad(x, w)) + hb[None, :, None, None])
        x = jnp.mean(x, axis=(2, 3))  # global average pool
        w = self._take(params, cur)
        fb = self._take(params, cur)
        return x @ w + fb


def teacher() -> EdgeNet:
    return EdgeNet(variant="dw")


def student() -> EdgeNet:
    return EdgeNet(variant="fuse")
