"""Training-step definitions (L2) — lowered AOT and executed by the Rust
runtime. Paper §5.3: the teacher trains with cross-entropy; NOS training
adds the Hinton-style soft-label distillation loss on teacher logits and
samples each scaffolded block's operator per step.

Optimizer: SGD with momentum 0.9 (the paper's NOS schedule uses SGD+0.9;
the cosine LR schedule lives in the Rust driver, which passes `lr` in)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import model as M
from compile import nos as N

MOMENTUM = 0.9
KD_ALPHA = 0.7  # weight of the distillation term in the NOS loss
KD_TEMP = 1.0  # paper uses plain soft labels (T = 1)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over the batch; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def kd_loss(student_logits: jax.Array, teacher_logits: jax.Array) -> jax.Array:
    """KL(teacher ‖ student) on softened logits (Hinton et al. [19])."""
    t = jax.nn.softmax(teacher_logits / KD_TEMP)
    logs = jax.nn.log_softmax(student_logits / KD_TEMP)
    logt = jax.nn.log_softmax(teacher_logits / KD_TEMP)
    return jnp.mean(jnp.sum(t * (logt - logs), axis=1)) * (KD_TEMP ** 2)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def _sgd(params: list, vel: list, grads: list, lr: jax.Array):
    new_vel = [MOMENTUM * v + g for v, g in zip(vel, grads)]
    new_params = [p - lr * v for p, v in zip(params, new_vel)]
    return new_params, new_vel


def make_plain_step(net: M.EdgeNet):
    """CE training step for a plain (teacher or in-place student) net.

    Signature (all f32 unless noted):
        (params..., vel..., x, y:int32, lr) ->
        (params'..., vel'..., loss, acc)
    """
    n = len(net.specs)

    def step(*args):
        params = list(args[:n])
        vel = list(args[n : 2 * n])
        x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]

        def loss_fn(ps):
            logits = net.apply(ps, x)
            return cross_entropy(logits, y), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc = accuracy(logits, y)
        new_params, new_vel = _sgd(params, vel, grads, lr)
        return tuple(new_params) + tuple(new_vel) + (loss, acc)

    return step, n


def make_nos_step(scaffold: N.Scaffold):
    """NOS training step (paper §4.1).

    Signature:
        (scaffold_params..., vel..., teacher_params...,
         x, y:int32, mask:(B_blocks,), lr) ->
        (scaffold_params'..., vel'..., loss, acc)

    The teacher parameters are frozen inputs (the pretrained depthwise
    net); mask samples each block's operator for this step.
    """
    n = scaffold.num_params_count = len(scaffold.specs)
    nt = scaffold.num_teacher_params

    def step(*args):
        params = list(args[:n])
        vel = list(args[n : 2 * n])
        teacher_params = list(args[2 * n : 2 * n + nt])
        x = args[2 * n + nt]
        y = args[2 * n + nt + 1]
        mask = args[2 * n + nt + 2]
        lr = args[2 * n + nt + 3]

        teacher_logits = scaffold.teacher.apply(teacher_params, x)

        def loss_fn(ps):
            logits = scaffold.apply(ps, x, mask)
            ce = cross_entropy(logits, y)
            kd = kd_loss(logits, teacher_logits)
            return (1.0 - KD_ALPHA) * ce + KD_ALPHA * kd, logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc = accuracy(logits, y)
        new_params, new_vel = _sgd(params, vel, grads, lr)
        return tuple(new_params) + tuple(new_vel) + (loss, acc)

    return step, n, nt


def make_infer(net: M.EdgeNet):
    """(params..., x) -> logits."""
    n = len(net.specs)

    def infer(*args):
        return (net.apply(list(args[:n]), args[n]),)

    return infer, n


def make_feature(net: M.EdgeNet, block: int):
    """(params..., x) -> block feature map (the Fig 12 hook)."""
    n = len(net.specs)

    def feat(*args):
        return (net.apply(list(args[:n]), args[n], feature_block=block),)

    return feat, n


def make_collapse(scaffold: N.Scaffold):
    """(scaffold_params...) -> (student_params...)."""
    n = len(scaffold.specs)

    def collapse(*args):
        return tuple(scaffold.collapse(list(args[:n])))

    return collapse, n
