//! End-to-end training driver (deliverable (b) + DESIGN.md E12): proves the
//! three layers compose. The Rust coordinator drives the AOT-compiled
//! JAX/Pallas train-step graphs through PJRT on a synthetic image corpus:
//!
//!   1. depthwise teacher        — trained from scratch
//!   2. FuSe student, in-place   — trained from scratch (paper §6.2)
//!   3. FuSe student, NOS        — scaffolded + distilled (paper §6.3)
//!
//! Loss curves land in `bench_results/*.csv`; accuracies and the Fig-12
//! feature-similarity contrast print at the end and are recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_e2e -- [steps]
//! ```

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("this example needs the PJRT runtime; rebuild with `--features xla`");
    std::process::exit(1);
}

#[cfg(feature = "xla")]
use fuseconv::runtime::pipeline::run_nos_pipeline;

#[cfg(feature = "xla")]
fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let dir = fuseconv::runtime::default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== end-to-end NOS training pipeline ({steps} steps/phase) ==");
    let t0 = std::time::Instant::now();
    let r = run_nos_pipeline(dir.to_str().unwrap(), steps, 0.06, 17, 256, true)
        .expect("pipeline");
    println!("\ntotal wall time: {:.1}s", t0.elapsed().as_secs_f64());

    // persist the loss curves
    let _ = std::fs::create_dir_all("bench_results");
    for (name, log) in [
        ("train_teacher.csv", &r.teacher_log),
        ("train_inplace.csv", &r.inplace_log),
        ("train_nos.csv", &r.nos_log),
    ] {
        let path = std::path::Path::new("bench_results").join(name);
        std::fs::write(&path, log.to_csv()).expect("write csv");
        println!("loss curve -> {}", path.display());
    }

    // the paper's qualitative claims, restated as checks on this run:
    let ok_order = r.nos_acc >= r.inplace_acc - 0.02;
    let ok_sim = r.feature_sim_nos > r.feature_sim_inplace;
    println!("\nclaims: NOS ≥ in-place accuracy: {ok_order}; NOS features closer to teacher: {ok_sim}");
}
