//! Hybrid-network search (paper §6.4, Fig 13/14): run the evolutionary
//! algorithm over the 2^N space of depthwise-vs-FuSe block choices for
//! MobileNetV3-Large, print the pareto frontier and compare against the
//! manual greedy-50% hybrid.
//!
//! ```sh
//! cargo run --release --example ea_search -- [pop] [iters]
//! ```

use fuseconv::coordinator::mapping::greedy_half;
use fuseconv::coordinator::search::{run_ea, AccuracyPredictor, EaConfig, TrainMethod};
use fuseconv::coordinator::{Evaluator, HybridSpace};
use fuseconv::nn::models;
use fuseconv::sim::SimConfig;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let pop: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(100);
    let iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);

    let base = models::by_name("mobilenet-v3-large").unwrap();
    println!("== EA hybrid search over {} ({} bottleneck blocks) ==", base.name, base.bottleneck_blocks().len());

    let ev = Evaluator::new(SimConfig::default());
    let space = HybridSpace::new(&base, &ev);
    let pred = AccuracyPredictor::for_space(&space);

    let t0 = std::time::Instant::now();
    let cfg = EaConfig { population: pop, iterations: iters, seed: 42, ..EaConfig::default() };
    let r = run_ea(&space, &pred, TrainMethod::Nos, &cfg);
    println!(
        "evaluated {} hybrids in {:.2}s ({:.0} evals/s)\n",
        r.evaluated,
        t0.elapsed().as_secs_f64(),
        r.evaluated as f64 / t0.elapsed().as_secs_f64()
    );

    println!("pareto frontier (accuracy ↑, latency ↓):");
    println!("{:>8} {:>9} {:>7}  mask (F=FuSe, d=depthwise)", "acc %", "lat ms", "#FuSe");
    for c in &r.frontier {
        let mask: String = c.mask.iter().map(|&m| if m { 'F' } else { 'd' }).collect();
        println!(
            "{:>8.2} {:>9.3} {:>7}  {}",
            c.acc,
            c.latency_ms,
            c.mask.iter().filter(|&&m| m).count(),
            mask
        );
    }

    // manual baseline for Fig 14's comparison
    let manual = greedy_half(&space);
    let m_acc = pred.predict_mask(&manual, TrainMethod::Nos);
    let m_lat = space.latency_ms(&manual);
    println!("\nmanual greedy-50% hybrid: acc {:.2}% @ {:.3} ms", m_acc, m_lat);
    let dominating = r
        .frontier
        .iter()
        .filter(|c| c.acc >= m_acc - 1e-9 && c.latency_ms <= m_lat + 1e-9)
        .count();
    println!("frontier points matching-or-dominating it: {dominating} (paper: EA beats manual)");
}
