//! End-to-end HTTP/SSE demo, no artifacts or features needed: boot the
//! HTTP frontend on an ephemeral port over the simulation pool, then
//! drive it with the bundled HTTP client — exactly what `fuseconv serve
//! --http-port` + `curl` do, in one process. The SSE sweep arrives as
//! incremental `row` events whose `data:` JSON is byte-identical to the
//! TCP framing (see PROTOCOL.md §HTTP mapping).
//!
//! ```sh
//! cargo run --release --example http_demo
//! ```

use fuseconv::coordinator::wire::encode_request_body;
use fuseconv::coordinator::{
    http_call, http_sse, ConfigPatch, Frame, HttpServer, Reply, Request, RequestBody, Router,
    SimServer,
};
use fuseconv::sim::FuseVariant;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // server side: simulation-only router behind the HTTP frontend
    let router = Router::new(SimServer::new(0));
    let server = HttpServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind");
    let addr = server.local_addr().to_string();
    println!("http on {addr}");
    let listener = std::thread::spawn(move || server.run().expect("serve"));
    let timeout = Duration::from_secs(120);

    // liveness + a one-shot simulate (the body is the terminal frame)
    let reply = http_call(&addr, "/healthz", None, None, timeout).expect("healthz");
    println!("GET /healthz -> {} {}", reply.status, reply.body.trim());
    let req = Request::new(
        1,
        RequestBody::Simulate {
            model: fuseconv::coordinator::ModelSpec::Zoo("mobilenet-v2".into()),
            variant: FuseVariant::Half,
            config: ConfigPatch::sized(16),
        },
    );
    let reply = http_call(&addr, "/v1/simulate", Some(&encode_request_body(&req)), None, timeout)
        .expect("simulate");
    match reply.response().expect("terminal frame").result {
        Ok(Reply::Sim(s)) => println!(
            "POST /v1/simulate -> {} on {}: {} cycles ({:.3} ms)",
            s.network, s.config_label, s.total_cycles, s.latency_ms
        ),
        other => println!("unexpected: {other:?}"),
    }

    // streamed sweep over SSE, with a running ETA from progress events
    let sweep = Request::new(
        2,
        RequestBody::Sweep {
            models: vec!["mobilenet-v3-small".into(), "mobilenet-v2".into()],
            variants: vec![FuseVariant::Base, FuseVariant::Half],
            configs: vec![
                ConfigPatch::sized(8),
                ConfigPatch::sized(16),
                ConfigPatch::sized(32),
            ],
        },
    );
    let t0 = Instant::now();
    let mut rows = 0usize;
    let resp = http_sse(
        &addr,
        "/v1/sweep",
        &encode_request_body(&sweep),
        None,
        timeout,
        |_, frame| match frame {
            Frame::Progress { done, total } if *done > 0 => {
                let elapsed = t0.elapsed().as_secs_f64();
                let eta = elapsed / *done as f64 * (total - done) as f64;
                println!("event: progress {done}/{total} cells, eta {eta:.2}s");
            }
            Frame::Progress { .. } => {}
            Frame::SearchRow(_) => {} // search streams only; a sweep never emits these
            Frame::Row(row) => {
                rows += 1;
                println!(
                    "event: row {:24} {:10} {:>3}x{:<3} -> {} cycles",
                    row.network,
                    row.variant.label(),
                    row.rows,
                    row.cols,
                    row.total_cycles
                );
            }
            Frame::Final(_) => {}
        },
    )
    .expect("sse sweep");
    match resp.result {
        Ok(Reply::Sweep(merged)) => println!(
            "sweep: {rows} rows streamed ({} merged) in {:.2}s",
            merged.len(),
            t0.elapsed().as_secs_f64()
        ),
        other => println!("unexpected: {other:?}"),
    }

    // stats, then a clean shutdown over HTTP
    let reply = http_call(&addr, "/v1/stats", None, None, timeout).expect("stats");
    println!("GET /v1/stats -> {}", reply.body.trim());
    let reply = http_call(&addr, "/v1/shutdown", Some("{}"), None, timeout).expect("shutdown");
    assert_eq!(reply.response().expect("ack").result, Ok(Reply::Done));
    listener.join().expect("listener");
    println!("clean shutdown");
}
