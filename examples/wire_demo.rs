//! End-to-end wire-protocol demo, no artifacts or features needed: boot
//! the TCP/JSON frontend on an ephemeral port with a mock inference
//! engine + the simulation pool, then drive mixed traffic through a
//! wire client — exactly what `fuseconv serve` / `fuseconv request` do,
//! in one process.
//!
//! ```sh
//! cargo run --release --example wire_demo
//! ```

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::wire::encode_response;
use fuseconv::coordinator::{
    ConfigPatch, MockEngine, ModelSpec, Reply, Request, RequestBody, Router, Server,
    SimServer, WireClient, WireServer,
};
use fuseconv::sim::FuseVariant;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // server side: mock engine (4 floats in, 2 out) + sim pool
    let router = Router::new(SimServer::new(0)).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind");
    let addr = server.local_addr().to_string();
    println!("listening on {addr}");
    let listener = std::thread::spawn(move || server.run().expect("serve"));

    // client side: one connection, mixed traffic
    let mut client = WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");
    let requests = vec![
        Request::new(1, RequestBody::Zoo),
        Request::new(
            2,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Half,
                config: ConfigPatch::sized(16),
            },
        ),
        Request::new(3, RequestBody::Infer { input: vec![1.0, 2.0, 3.0, 4.0] }),
        Request::new(
            4,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half],
                configs: vec![ConfigPatch::sized(8), ConfigPatch::sized(16)],
            },
        ),
        Request::new(5, RequestBody::Stats),
    ];
    for req in &requests {
        client.send(req).expect("send");
    }
    for _ in 0..requests.len() {
        let resp = client.recv().expect("recv");
        match &resp.result {
            Ok(Reply::Zoo(entries)) => println!("zoo: {} models", entries.len()),
            Ok(Reply::Sim(s)) => {
                println!(
                    "sim: {} on {} -> {} cycles ({:.3} ms)",
                    s.network, s.config_label, s.total_cycles, s.latency_ms
                )
            }
            Ok(Reply::Infer(r)) => {
                println!("infer: output {:?} (batch {})", r.output, r.batch_size)
            }
            Ok(Reply::Sweep(rows)) => println!("sweep: {} cells", rows.len()),
            Ok(Reply::Stats(s)) => println!(
                "stats: {} sims, cache {}h/{}m, raw frame: {}",
                s.sim_completed,
                s.cache_hits,
                s.cache_misses,
                encode_response(&resp)
            ),
            Ok(Reply::Done) => println!("done"),
            Err(e) => println!("error: {e}"),
        }
    }

    // clean shutdown over the wire
    let resp = client
        .roundtrip(&Request::new(6, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    listener.join().expect("listener");
    println!("clean shutdown");
}
