//! End-to-end wire-protocol demo, no artifacts or features needed: boot
//! the TCP/JSON frontend on an ephemeral port with a mock inference
//! engine + the simulation pool, then drive mixed traffic through a
//! wire client — exactly what `fuseconv serve` / `fuseconv request` do,
//! in one process.
//!
//! Protocol v2 is a frame-stream contract: the sweep below arrives as
//! incremental `Row` frames (consumed with a running ETA) instead of one
//! giant end-of-grid reply.
//!
//! ```sh
//! cargo run --release --example wire_demo
//! ```

use fuseconv::coordinator::batcher::BatchPolicy;
use fuseconv::coordinator::wire::encode_response;
use fuseconv::coordinator::{
    ConfigPatch, Frame, MockEngine, ModelSpec, Reply, Request, RequestBody, Router, Server,
    SimServer, WireClient, WireServer,
};
use fuseconv::sim::FuseVariant;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // server side: mock engine (4 floats in, 2 out) + sim pool
    let router = Router::new(SimServer::new(0)).with_engine(Server::start(
        MockEngine::new(4, 2, 8),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    ));
    let server = WireServer::bind("127.0.0.1:0", Arc::new(router)).expect("bind");
    let addr = server.local_addr().to_string();
    println!("listening on {addr}");
    let listener = std::thread::spawn(move || server.run().expect("serve"));

    // client side: one connection, point queries first
    let mut client = WireClient::connect(&addr, Duration::from_secs(60)).expect("connect");
    let requests = vec![
        Request::new(1, RequestBody::Zoo),
        Request::new(
            2,
            RequestBody::Simulate {
                model: ModelSpec::Zoo("mobilenet-v2".into()),
                variant: FuseVariant::Half,
                config: ConfigPatch::sized(16),
            },
        ),
        Request::new(3, RequestBody::Infer { input: vec![1.0, 2.0, 3.0, 4.0] }),
    ];
    for req in &requests {
        client.send(req).expect("send");
    }
    for req in &requests {
        let resp = client.recv_response(req.id).expect("recv");
        match &resp.result {
            Ok(Reply::Zoo(entries)) => println!("zoo: {} models", entries.len()),
            Ok(Reply::Sim(s)) => println!(
                "sim: {} on {} -> {} cycles ({:.3} ms)",
                s.network, s.config_label, s.total_cycles, s.latency_ms
            ),
            Ok(Reply::Infer(r)) => {
                println!("infer: output {:?} (batch {})", r.output, r.batch_size)
            }
            other => println!("unexpected: {other:?}"),
        }
    }

    // streamed sweep: consume Row frames as the grid completes, with a
    // running ETA from the progress counter
    client
        .send(&Request::new(
            4,
            RequestBody::Sweep {
                models: vec!["mobilenet-v3-small".into(), "mobilenet-v2".into()],
                variants: vec![FuseVariant::Base, FuseVariant::Half],
                configs: vec![
                    ConfigPatch::sized(8),
                    ConfigPatch::sized(16),
                    ConfigPatch::sized(32),
                ],
            },
        ))
        .expect("send sweep");
    let t0 = Instant::now();
    let mut rows = 0usize;
    loop {
        match client.recv_frame(4).expect("sweep frame") {
            Frame::Progress { done, total } if done > 0 => {
                let elapsed = t0.elapsed().as_secs_f64();
                let eta = elapsed / done as f64 * (total - done) as f64;
                println!("progress: {done}/{total} cells, eta {eta:.2}s");
            }
            Frame::Progress { .. } => {}
            Frame::SearchRow(_) => {} // search streams only; a sweep never emits these
            Frame::Row(row) => {
                rows += 1;
                println!(
                    "row: {:24} {:10} {:>3}x{:<3} -> {} cycles ({:.3} ms)",
                    row.network,
                    row.variant.label(),
                    row.rows,
                    row.cols,
                    row.total_cycles,
                    row.latency_ms
                );
            }
            Frame::Final(result) => {
                assert_eq!(result, Ok(Reply::Done));
                break;
            }
        }
    }
    println!("sweep: {rows} rows streamed in {:.2}s", t0.elapsed().as_secs_f64());

    // stats, printed as the raw wire frame
    let resp = client.roundtrip(&Request::new(5, RequestBody::Stats)).expect("stats");
    if let Ok(Reply::Stats(s)) = &resp.result {
        println!(
            "stats: {} sims, cache {}h/{}m, raw frame: {}",
            s.sim_completed,
            s.cache_hits,
            s.cache_misses,
            encode_response(&resp)
        );
    }

    // clean shutdown over the wire
    let resp = client
        .roundtrip(&Request::new(6, RequestBody::Shutdown))
        .expect("shutdown ack");
    assert_eq!(resp.result, Ok(Reply::Done));
    listener.join().expect("listener");
    println!("clean shutdown");
}
