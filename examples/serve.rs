//! Inference-serving driver (deliverable (b), DESIGN.md S11): load the
//! AOT-compiled FuSe student model, serve a stream of single-image
//! requests through the dynamic batcher, and report latency/throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve -- [requests]
//! ```

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("this example needs the PJRT runtime; rebuild with `--features xla`");
    std::process::exit(1);
}

#[cfg(feature = "xla")]
use fuseconv::coordinator::batcher::BatchPolicy;
#[cfg(feature = "xla")]
use fuseconv::coordinator::server::Server;
#[cfg(feature = "xla")]
use fuseconv::coordinator::Reply;
#[cfg(feature = "xla")]
use fuseconv::runtime::{default_artifacts_dir, Manifest, PjrtEngine, Synth};
#[cfg(feature = "xla")]
use std::time::{Duration, Instant};

#[cfg(feature = "xla")]
fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let dir = default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let manifest = Manifest::load(&dir).unwrap();
    let hw = manifest.const_usize("image_hw").unwrap();
    let classes = manifest.const_usize("num_classes").unwrap();

    println!("== serving the FuSe student model (batch≤8, 5 ms deadline) ==");
    let server = Server::start_with(
        move || PjrtEngine::from_artifacts(&dir, "student_init.bin").unwrap(),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) },
    );

    // open-loop client: bursts of 4 requests with small gaps
    let mut synth = Synth::new(hw, classes, 2026);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let (x, _) = synth.batch(1);
        pending.push(server.submit(x));
        if i % 4 == 3 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut correct_shape = 0;
    for ticket in pending {
        match ticket.wait_deadline(Duration::from_secs(300)).result {
            Ok(Reply::Infer(r)) if r.output.len() == classes => correct_shape += 1,
            Ok(_) => {}
            Err(e) => panic!("request failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    let s = stats.latency_summary().unwrap();

    println!("served {} requests ({correct_shape} well-formed) in {wall:.2}s", stats.served);
    println!(
        "throughput {:.1} req/s over {} batches (mean batch {:.2})",
        stats.served as f64 / wall,
        stats.batches,
        stats.mean_batch()
    );
    println!(
        "latency: p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms  max {:.1} ms",
        s.p50 / 1e3,
        s.p90 / 1e3,
        s.p99 / 1e3,
        s.max / 1e3
    );
}
