//! Quickstart: the paper's headline result in one run.
//!
//! Simulates MobileNetV2 on a 16×16 systolic array (paper Table 1 config)
//! with depthwise bottlenecks, then with FuSeConv + ST-OS, and prints the
//! speedup, utilization contrast, and the hardware cost of ST-OS support.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fuseconv::nn::models;
use fuseconv::nn::{fuse_all, OpClass, Variant};
use fuseconv::sim::{simulate_network, SimConfig};
use fuseconv::vlsi;

fn main() {
    let cfg = SimConfig::default(); // 16x16 @ 1 GHz, 3×64 KiB SRAM, OS + ST-OS
    let base = models::by_name("mobilenet-v2").expect("zoo");
    let fuse = fuse_all(&base, Variant::Half);

    println!("== FuSeConv quickstart (paper: Ganesan & Kumar, 2021) ==\n");
    println!(
        "{}: {:.1} M MACs, {:.2} M params",
        base.name,
        base.macs_millions(),
        base.params_millions()
    );
    println!(
        "{}: {:.1} M MACs, {:.2} M params  (drop-in replacement)\n",
        fuse.name,
        fuse.macs_millions(),
        fuse.params_millions()
    );

    let sb = simulate_network(&base, &cfg);
    let sf = simulate_network(&fuse, &cfg);
    println!("latency on 16x16 systolic array @ 1 GHz:");
    println!(
        "  baseline (depthwise, OS): {:>8.3} ms   utilization {:>5.1}%",
        sb.latency_ms,
        100.0 * sb.overall_utilization()
    );
    println!(
        "  FuSeConv (ST-OS):         {:>8.3} ms   utilization {:>5.1}%",
        sf.latency_ms,
        100.0 * sf.overall_utilization()
    );
    println!(
        "  speedup: {:.2}x  (paper reports 7.01–9.36x for FuSe-Half)\n",
        sb.total_cycles as f64 / sf.total_cycles as f64
    );

    let by = sb.cycles_by_class();
    let dw_share = *by.get(&OpClass::Depthwise).unwrap_or(&0) as f64 / sb.total_cycles as f64;
    println!(
        "why: depthwise convolutions are {:.0}% of baseline latency at ~{:.0}% PE\n\
         utilization (not a systolic algorithm, §2); FuSe's 1D convolutions map\n\
         one-per-row under ST-OS and keep the array busy.\n",
        100.0 * dw_share,
        100.0
            * sb.layers
                .iter()
                .filter(|l| l.class == OpClass::Depthwise)
                .map(|l| l.utilization)
                .fold(0.0, f64::max)
    );

    let o = vlsi::st_os_overhead(16, 16);
    println!(
        "hardware cost of ST-OS on 16x16: {:.1}% area, {:.1}% power (paper: 3.2%/6.7%)",
        o.area_pct(),
        o.power_pct()
    );
}
